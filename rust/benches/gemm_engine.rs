//! GEMM engine before/after: seed baselines vs the plan/execute engine,
//! and the engine's two data paths against each other.
//!
//! Emits `BENCH_gemm_engine.json` so the perf trajectory is tracked
//! from PR 1 onward. Measured per mode (dense / int8 / fallback at
//! ~0%, ~5%, ~25% rate), Natural-equivalent Random vs worst-case
//! Sequential placement, 1 and N threads:
//!
//! * `gops_seed`     — retained pre-engine kernel (per-call conversion,
//!                     strided B, contiguous chunking)
//! * `gops_engine`   — the public wrappers (fresh plan per call, cached
//!                     packed operands, default = Int8 data path)
//! * `gops_plan_sim` — plan built once on `DataPath::SimF32` (f32 code
//!                     copies), executed repeatedly
//! * `gops_plan_i8`  — plan built once on `DataPath::Int8` (true i8
//!                     operands, i32 accumulation) — the steady-state
//!                     training path
//!
//! Also sweeps the i8 path across **every microkernel backend** on
//! the host (scalar / sse2 / avx2 / avx512vnni / neon — the
//! `PALLAS_KERNEL` choices), reports per-backend Gops plus the
//! selected backend and detected CPU features in the JSON, measures
//! the vectorized-vs-scalar f32 path on the SimF32 plan (the
//! `f32_simd_vs_scalar` criterion — the v2 re-anchor's payoff),
//! installs the fastest measured backend as the process default via
//! the calibration, reports packed bytes per operand (the 4x B-panel
//! shrink the i8 path buys), records the measured
//! `SubstrateCalibration` the cost model consumes in place of its
//! ad-hoc fallback-overhead constant, measures the dispatch
//! overhead of the persistent worker pool vs per-call scoped threads
//! on a small-m GEMM (the `dispatch_overhead` fields — PR 7's
//! payoff), A/Bs the vectorized i32→f32 widening slot (the
//! `widen_simd_vs_scalar` criterion), and sweeps the i8 plan across
//! shard counts S = 1/2/4 (the `shard_scaling` fields +
//! `shard_s2_vs_s1` criterion — sharding is bit-neutral, so this is
//! pure perf trajectory).
//!
//! Set `BENCH_SMOKE=1` for a seconds-long CI smoke run (small dim,
//! short iterations) that keeps this binary from rotting.

use dbfq::costmodel::{rtx4090, SubstrateCalibration};
use dbfq::gemm::{self, kernels, DataPath, GemmPlan, Placement};
use dbfq::quant::{self, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::{bench, gops, Table};
use dbfq::util::json::{obj, Json};
use dbfq::util::pool;
use dbfq::util::rng::Pcg64;
use dbfq::util::threadpool::default_threads;
use dbfq::util::Mat;

const BLOCK: usize = 128;

fn measure<F: FnMut()>(dim: usize, target_ms: u64, f: F) -> f64 {
    let s = bench(f, target_ms);
    gops(dim, dim, dim, s.median_secs())
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let dim: usize = if smoke { 256 } else { 1024 };
    let target_ms: u64 = if smoke { 20 } else { 200 };

    println!("\n================================================");
    println!("GEMM engine vs seed baselines ({dim}^3, block {BLOCK})");
    println!("================================================");

    let nthreads = default_threads().max(2);
    let thread_counts = [1usize, nthreads];

    let mut rng = Pcg64::new(0xE2612E);
    let a = Mat::randn(dim, dim, 1.0, &mut rng);
    // channel-structured outliers (paper §4.1) so fallback has texture
    let mut a_out = a.clone();
    for c in 0..dim {
        if c % 97 == 0 {
            for r in 0..dim {
                if rng.uniform() < 0.3 {
                    a_out.data[r * dim + c] =
                        200.0 * (1.0 + rng.uniform_f32());
                }
            }
        }
    }
    let b = Mat::randn(dim, dim, 1.0, &mut rng);
    let qa = quant::block_quant(&a, BLOCK, INT8_LEVELS,
                                Rounding::Nearest);
    let qb = quant::block_quant(&b, BLOCK, INT8_LEVELS,
                                Rounding::Nearest);
    let probe = quant::fallback_quant(&a_out, f32::INFINITY, BLOCK,
                                      INT8_LEVELS, Criterion::AbsMax);

    let mut table = Table::new(&["mode", "rate", "placement", "thr",
                                 "seed", "engine", "plan.sim",
                                 "plan.i8", "i8/sim"]);
    let mut dense_rows = Vec::new();
    let mut int8_rows = Vec::new();
    let mut fb_rows = Vec::new();

    // -- dense ----------------------------------------------------------
    for &threads in &thread_counts {
        let g_seed = measure(dim, target_ms, || {
            std::hint::black_box(gemm::matmul_baseline(&a, &b, threads));
        });
        let g_eng = measure(dim, target_ms, || {
            std::hint::black_box(gemm::matmul(&a, &b, threads));
        });
        let plan = GemmPlan::new_dense(&a, &b, threads);
        let g_plan = measure(dim, target_ms, || {
            std::hint::black_box(plan.execute());
        });
        table.row(&[
            "dense".into(), "-".into(), "-".into(),
            threads.to_string(),
            format!("{g_seed:.2}"), format!("{g_eng:.2}"),
            format!("{g_plan:.2}"), "-".into(), "-".into(),
        ]);
        dense_rows.push(obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("gops_seed", Json::Num(g_seed)),
            ("gops_engine", Json::Num(g_eng)),
            ("gops_plan", Json::Num(g_plan)),
        ]));
    }

    // -- int8 block: seed vs wrapper vs both data paths -----------------
    let mut int8_speedup_1t = 0.0;
    let mut int8_i8_vs_sim_nt = 0.0;
    for &threads in &thread_counts {
        let g_seed = measure(dim, target_ms, || {
            std::hint::black_box(
                gemm::block_gemm_baseline(&qa, &qb, threads));
        });
        let g_eng = measure(dim, target_ms, || {
            std::hint::black_box(gemm::block_gemm(&qa, &qb, threads));
        });
        let plan_sim = GemmPlan::new_int8_path(&qa, &qb, threads,
                                               DataPath::SimF32);
        let g_sim = measure(dim, target_ms, || {
            std::hint::black_box(plan_sim.execute());
        });
        let plan_i8 = GemmPlan::new_int8_path(&qa, &qb, threads,
                                              DataPath::Int8);
        let g_i8 = measure(dim, target_ms, || {
            std::hint::black_box(plan_i8.execute());
        });
        if threads == 1 {
            int8_speedup_1t = g_eng / g_seed;
        }
        if threads == nthreads {
            int8_i8_vs_sim_nt = g_i8 / g_sim;
        }
        table.row(&[
            "int8".into(), "0.00".into(), "-".into(),
            threads.to_string(),
            format!("{g_seed:.2}"), format!("{g_eng:.2}"),
            format!("{g_sim:.2}"), format!("{g_i8:.2}"),
            format!("{:.2}x", g_i8 / g_sim),
        ]);
        int8_rows.push(obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("gops_seed", Json::Num(g_seed)),
            ("gops_engine", Json::Num(g_eng)),
            ("gops_plan_sim", Json::Num(g_sim)),
            ("gops_plan_i8", Json::Num(g_i8)),
        ]));
    }

    // -- i8 path per kernel backend -------------------------------------
    // The acceptance bar: every SIMD backend must beat (or at worst
    // match) the scalar floor on the default shapes.
    let mut backend_rows = Vec::new();
    let mut g_backend_scalar = 0.0f64;
    let mut g_backend_best: (&'static str, f64) = ("scalar", 0.0);
    for kn in kernels::available() {
        let plan = GemmPlan::new_int8_path(&qa, &qb, nthreads,
                                           DataPath::Int8)
            .with_kernels(kn);
        let g = measure(dim, target_ms, || {
            std::hint::black_box(plan.execute());
        });
        if kn.name == "scalar" {
            g_backend_scalar = g;
        }
        if g > g_backend_best.1 {
            g_backend_best = (kn.name, g);
        }
        table.row(&[
            format!("int8[{}]", kn.name), "0.00".into(), "-".into(),
            nthreads.to_string(), "-".into(), "-".into(), "-".into(),
            format!("{g:.2}"),
            format!("{:.2}x", g / g_backend_scalar.max(1e-12)),
        ]);
        backend_rows.push(obj(vec![
            ("name", Json::Str(kn.name.into())),
            ("threads", Json::Num(nthreads as f64)),
            ("gops_plan_i8", Json::Num(g)),
        ]));
    }
    let simd_vs_scalar = g_backend_best.1 / g_backend_scalar.max(1e-12);

    // -- f32 SIMD vs scalar on the SimF32 path --------------------------
    // The v2 re-anchor's payoff: the same plan, same bits, with the
    // runtime FMA dispatch forced onto the scalar mul_add floor vs
    // left vectorized. (Results are bit-identical by contract — the
    // kernel tests assert that; this measures the speed gap.)
    let f32_simd_vs_scalar = {
        let plan_sim = GemmPlan::new_int8_path(&qa, &qb, nthreads,
                                               DataPath::SimF32);
        kernels::set_f32_simd_enabled(false);
        let g_scalar = measure(dim, target_ms, || {
            std::hint::black_box(plan_sim.execute());
        });
        kernels::set_f32_simd_enabled(true);
        let g_simd = measure(dim, target_ms, || {
            std::hint::black_box(plan_sim.execute());
        });
        println!(
            "\nf32 SimF32 path @ {nthreads} threads: vectorized \
             {g_simd:.2} Gops vs scalar mul_add {g_scalar:.2} Gops = \
             {:.2}x (target >= 1.0x)",
            g_simd / g_scalar.max(1e-12)
        );
        g_simd / g_scalar.max(1e-12)
    };

    // -- widen SIMD vs scalar on the Int8 path --------------------------
    // The vectorized i32→f32 widening slot (per-lane cvt, bit-equal to
    // the scalar floor by the correctly-rounded-conversion argument).
    // Widening is a small share of the i8 inner loop, so this mostly
    // confirms the slot doesn't regress; debug builds route to scalar
    // either way, so measure in release only.
    let widen_simd_vs_scalar = {
        let plan_i8 = GemmPlan::new_int8_path(&qa, &qb, nthreads,
                                              DataPath::Int8);
        kernels::set_widen_simd_enabled(false);
        let g_scalar = measure(dim, target_ms, || {
            std::hint::black_box(plan_i8.execute());
        });
        kernels::set_widen_simd_enabled(true);
        let g_simd = measure(dim, target_ms, || {
            std::hint::black_box(plan_i8.execute());
        });
        println!(
            "\nwiden (i32→f32) @ {nthreads} threads: vectorized \
             {g_simd:.2} Gops vs scalar {g_scalar:.2} Gops = \
             {:.2}x (target >= 1.0x on wide panels)",
            g_simd / g_scalar.max(1e-12)
        );
        g_simd / g_scalar.max(1e-12)
    };

    // -- shard scaling: i8 plan at S = 1 / 2 / 4 ------------------------
    // Per-shard LPT schedules with worker-affinity hints; bit-identical
    // output by contract (tests/shard_prop.rs), so this sweep records
    // what sharding costs or buys on this host's topology.
    let mut shard_rows = Vec::new();
    let mut shard_s2_over_s1 = 0.0f64;
    {
        let mut g_s1 = 0.0f64;
        for shards in [1usize, 2, 4] {
            let plan = GemmPlan::new_int8_path(&qa, &qb, nthreads,
                                               DataPath::Int8)
                .with_shards(shards);
            let g = measure(dim, target_ms, || {
                std::hint::black_box(plan.execute());
            });
            if shards == 1 {
                g_s1 = g;
            }
            if shards == 2 {
                shard_s2_over_s1 = g / g_s1.max(1e-12);
            }
            println!(
                "shard scaling @ {nthreads} threads: S={shards} \
                 (effective {}) {g:.2} Gops = {:.2}x S=1",
                plan.shard_count(), g / g_s1.max(1e-12)
            );
            shard_rows.push(obj(vec![
                ("shards", Json::Num(shards as f64)),
                ("shards_effective",
                 Json::Num(plan.shard_count() as f64)),
                ("threads", Json::Num(nthreads as f64)),
                ("gops_plan_i8", Json::Num(g)),
                ("vs_s1", Json::Num(g / g_s1.max(1e-12))),
            ]));
        }
    }

    // -- dispatch overhead: small-m GEMM, pool vs scoped ----------------
    // The persistent pool's payoff case: a GEMM too small to amortize
    // per-call thread spawns. The plan and the output buffer are both
    // reused across calls (`execute_into`), so the only difference
    // between the two runs is the dispatch mechanism — parked pool
    // workers vs a fresh `std::thread::scope` per call.
    let (dispatch_obj, dispatch_ratio) = {
        let db = 32usize.min(BLOCK);
        let (dm, dk, dn) = (32usize, 128usize, 128usize);
        let mut drng = Pcg64::new(0xD15);
        let sa = Mat::randn(dm, dk, 1.0, &mut drng);
        let sb = Mat::randn(dk, dn, 1.0, &mut drng);
        let qsa = quant::block_quant(&sa, db, INT8_LEVELS,
                                     Rounding::Nearest);
        let qsb = quant::block_quant(&sb, db, INT8_LEVELS,
                                     Rounding::Nearest);
        let plan = GemmPlan::new_int8(&qsa, &qsb, nthreads);
        let mut out = Mat::zeros(0, 0);
        pool::set_pool_enabled(true);
        plan.execute_into(&mut out); // warm pool + workspaces
        let pooled_us = bench(|| plan.execute_into(&mut out),
                              target_ms)
            .median_secs() * 1e6;
        pool::set_pool_enabled(false);
        plan.execute_into(&mut out);
        let scoped_us = bench(|| plan.execute_into(&mut out),
                              target_ms)
            .median_secs() * 1e6;
        pool::set_pool_enabled(true);
        let ratio = scoped_us / pooled_us.max(1e-9);
        println!(
            "\ndispatch overhead ({dm}x{dk}x{dn} i8, {nthreads} \
             threads): pooled {pooled_us:.1} us vs scoped \
             {scoped_us:.1} us = {ratio:.2}x (target: pooled < \
             scoped)"
        );
        (obj(vec![
            ("m", Json::Num(dm as f64)),
            ("n", Json::Num(dn as f64)),
            ("k", Json::Num(dk as f64)),
            ("block", Json::Num(db as f64)),
            ("threads", Json::Num(nthreads as f64)),
            ("pooled_us", Json::Num(pooled_us)),
            ("scoped_us", Json::Num(scoped_us)),
            ("scoped_over_pooled", Json::Num(ratio)),
        ]), ratio)
    };

    // -- fallback: rate x placement x threads ---------------------------
    let mut seq_gap_worst: f64 = 0.0;
    let mut fb_i8_vs_sim_nt = 0.0;
    for rate in [0.0f64, 0.05, 0.25] {
        let theta = quant::theta_for_rate(&probe.metric, rate);
        let fa = quant::fallback_quant(&a_out, theta, BLOCK,
                                       INT8_LEVELS, Criterion::AbsMax);
        let got_rate = fa.fallback_rate();
        let mut by_placement = Vec::new();
        for placement in [Placement::Random(9), Placement::Sequential] {
            let u = gemm::remap_placement(&fa, placement);
            for &threads in &thread_counts {
                let g_seed = measure(dim, target_ms, || {
                    std::hint::black_box(gemm::fallback_gemm_baseline(
                        &fa, &qb, &u, threads));
                });
                let g_eng = measure(dim, target_ms, || {
                    std::hint::black_box(
                        gemm::fallback_gemm(&fa, &qb, &u, threads));
                });
                let plan_sim = GemmPlan::new_fallback_path(
                    &fa, &qb, &u, threads, DataPath::SimF32);
                let g_sim = measure(dim, target_ms, || {
                    std::hint::black_box(plan_sim.execute());
                });
                let plan_i8 = GemmPlan::new_fallback_path(
                    &fa, &qb, &u, threads, DataPath::Int8);
                let g_i8 = measure(dim, target_ms, || {
                    std::hint::black_box(plan_i8.execute());
                });
                table.row(&[
                    "fallback".into(),
                    format!("{got_rate:.2}"),
                    format!("{placement:?}"),
                    threads.to_string(),
                    format!("{g_seed:.2}"), format!("{g_eng:.2}"),
                    format!("{g_sim:.2}"), format!("{g_i8:.2}"),
                    format!("{:.2}x", g_i8 / g_sim),
                ]);
                fb_rows.push(obj(vec![
                    ("rate", Json::Num(got_rate)),
                    ("placement",
                     Json::Str(format!("{placement:?}"))),
                    ("threads", Json::Num(threads as f64)),
                    ("gops_seed", Json::Num(g_seed)),
                    ("gops_engine", Json::Num(g_eng)),
                    ("gops_plan_sim", Json::Num(g_sim)),
                    ("gops_plan_i8", Json::Num(g_i8)),
                ]));
                if threads == nthreads {
                    by_placement.push(g_eng);
                    if matches!(placement, Placement::Random(_))
                        && rate == 0.25
                    {
                        fb_i8_vs_sim_nt = g_i8 / g_sim;
                    }
                }
            }
        }
        // engine Sequential-vs-Random gap at N threads for this rate
        if by_placement.len() == 2 && by_placement[0] > 0.0 {
            let gap = (1.0 - by_placement[1] / by_placement[0]).abs();
            seq_gap_worst = seq_gap_worst.max(gap);
        }
    }
    table.print();

    // -- packed operand footprint (resident bytes per operand) ----------
    let b_panels_f32 = qb.col_panels().bytes();
    let b_panels_i8 = qb.col_panels_i8().bytes();
    let a_codes_i8 = qa.q.len();
    let a_codes_f32 = 4 * qa.q.len();
    println!(
        "\npacked B operand: {} KiB (i8 panels) vs {} KiB (f32 \
         panels); A codes: {} KiB (i8, zero-copy) vs {} KiB (f32)",
        b_panels_i8 / 1024, b_panels_f32 / 1024,
        a_codes_i8 / 1024, a_codes_f32 / 1024
    );

    println!(
        "\nkernel backends @ {nthreads} threads: best {} \
         {:.2} Gops = {simd_vs_scalar:.2}x scalar \
         (target: SIMD >= scalar); detected features: {:?}",
        g_backend_best.0, g_backend_best.1, kernels::cpu_features()
    );

    // -- measured substrate calibration → cost model --------------------
    let cal_dim = if smoke { 128 } else { 512 };
    let cal = SubstrateCalibration::measure(cal_dim, BLOCK, nthreads);
    // From here on, plans in this process default to the backend the
    // calibration measured fastest (PALLAS_KERNEL still wins).
    let installed = cal.install_fastest_backend();
    println!(
        "calibration installed fastest backend: {} \
         (headline backend was {})",
        installed.unwrap_or("<none>"), cal.backend
    );
    let slope = cal.fallback_overhead_per_rate();
    let g4090 = rtx4090();
    let proj25 = 2.0 * (4096f64).powi(3)
        / cal.projected_int8_secs(&g4090, 4096, 4096, 4096, 128, 0.25)
        / 1e12;
    println!(
        "\nmeasured fallback overhead: {:.2}x per unit rate \
         (cost model's ad-hoc constant: 1.0x)",
        slope
    );
    println!(
        "4090 projection @ 25% rate with measured slope: {proj25:.0} \
         Tops"
    );
    println!(
        "engine vs seed int8 (1 thread): {int8_speedup_1t:.2}x \
         (target >= 1.25x)"
    );
    println!(
        "i8 vs sim data path @ {nthreads} threads: int8 \
         {int8_i8_vs_sim_nt:.2}x, fallback {fb_i8_vs_sim_nt:.2}x \
         (target >= 1.5x)"
    );
    println!(
        "calibration datapath speedup: {:.2}x",
        cal.datapath_speedup()
    );
    println!(
        "worst Sequential-vs-Random engine gap @ {nthreads} threads: \
         {:.1}% (target <= 10%)",
        100.0 * seq_gap_worst
    );

    let report = obj(vec![
        ("bench", Json::Str("gemm_engine".into())),
        ("smoke", Json::Bool(smoke)),
        ("dims", obj(vec![
            ("m", Json::Num(dim as f64)),
            ("n", Json::Num(dim as f64)),
            ("k", Json::Num(dim as f64)),
            ("block", Json::Num(BLOCK as f64)),
        ])),
        ("threads_max", Json::Num(nthreads as f64)),
        ("kernel_backend",
         Json::Str(GemmPlan::new_int8_path(&qa, &qb, nthreads,
                                           DataPath::Int8)
             .kernel_backend()
             .into())),
        ("cpu_features",
         Json::Arr(kernels::cpu_features()
             .iter()
             .map(|&f| Json::Str(f.into()))
             .collect())),
        ("backends", Json::Arr(backend_rows)),
        ("dense", Json::Arr(dense_rows)),
        ("int8", Json::Arr(int8_rows)),
        ("fallback", Json::Arr(fb_rows)),
        ("packed_bytes", obj(vec![
            ("b_panels_f32", Json::Num(b_panels_f32 as f64)),
            ("b_panels_i8", Json::Num(b_panels_i8 as f64)),
            ("a_codes_f32", Json::Num(a_codes_f32 as f64)),
            ("a_codes_i8", Json::Num(a_codes_i8 as f64)),
        ])),
        ("dispatch_overhead", dispatch_obj),
        ("shard_scaling", Json::Arr(shard_rows)),
        ("criteria", obj(vec![
            ("int8_engine_vs_seed_1t", Json::Num(int8_speedup_1t)),
            ("int8_i8_vs_sim", Json::Num(int8_i8_vs_sim_nt)),
            ("fallback_i8_vs_sim", Json::Num(fb_i8_vs_sim_nt)),
            ("seq_vs_random_gap_worst", Json::Num(seq_gap_worst)),
            ("simd_vs_scalar", Json::Num(simd_vs_scalar)),
            ("f32_simd_vs_scalar", Json::Num(f32_simd_vs_scalar)),
            ("widen_simd_vs_scalar",
             Json::Num(widen_simd_vs_scalar)),
            ("shard_s2_vs_s1", Json::Num(shard_s2_over_s1)),
            ("dispatch_scoped_over_pooled",
             Json::Num(dispatch_ratio)),
        ])),
        ("calibration", obj(vec![
            ("dense_gops", Json::Num(cal.dense_gops)),
            ("int8_gops", Json::Num(cal.int8_gops)),
            ("int8_sim_gops", Json::Num(cal.int8_sim_gops)),
            ("datapath_speedup", Json::Num(cal.datapath_speedup())),
            ("fallback_overhead_per_rate", Json::Num(slope)),
            ("projected_4090_tops_at_25pct", Json::Num(proj25)),
            ("backend", Json::Str(cal.backend.into())),
            ("installed_backend",
             Json::Str(installed.unwrap_or("<none>").into())),
            ("per_backend", Json::Arr(
                cal.per_backend
                    .iter()
                    .map(|&(name, g)| obj(vec![
                        ("name", Json::Str(name.into())),
                        ("gops", Json::Num(g)),
                    ]))
                    .collect(),
            )),
        ])),
    ]);
    std::fs::write("BENCH_gemm_engine.json", report.to_string())
        .expect("write BENCH_gemm_engine.json");
    println!("\nwrote BENCH_gemm_engine.json");
}
