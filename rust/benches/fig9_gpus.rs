//! Fig 9 (Appendix B): Fallback GEMM throughput on 3090 / L20 / A800,
//! random vs sequential placement, plus the INT8-vs-BF16 speedup each
//! architecture admits.

#[path = "common.rs"]
mod common;

use dbfq::costmodel::{a800, l20, rtx3090, rtx4090};
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 9 — fallback GEMM across GPUs",
                   "Appendix B: 2.47x on 3090, 1.85x on L20, less on \
                    A800 (2x int8:bf16 + weak CUDA cores)");

    let dim = 4096usize;
    let rate = 0.2;
    let mut t = Table::new(&["gpu", "bf16(Tflops-eq)", "int8-fb random",
                             "int8-fb sequential", "speedup vs bf16"]);
    for gpu in [rtx4090(), rtx3090(), l20(), a800()] {
        let bf16_tops =
            2.0 * (dim * dim * dim) as f64
            / gpu.bf16_gemm_secs(dim, dim, dim) / 1e12;
        let rnd = gpu.int8_gemm_tops(dim, dim, dim, 128, rate);
        let seq = gpu.int8_gemm_tops_worst(dim, dim, dim, 128, rate);
        t.row(&[
            gpu.name.into(),
            format!("{bf16_tops:.0}"),
            format!("{rnd:.0}"),
            format!("{seq:.0}"),
            format!("{:.2}x", rnd / bf16_tops),
        ]);
    }
    t.print();
    println!("\npaper shape: 3090 gains most (4x int8 ratio), A800 \
              least (2x ratio, dequant-bound)");
}
