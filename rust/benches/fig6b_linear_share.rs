//! Fig 6(b): fraction of forward computation spent in Linear layers
//! across Qwen-2.5 model sizes — the argument for leaving non-linear
//! layers in BF16 (their share vanishes as models grow).

#[path = "common.rs"]
mod common;

use dbfq::model::linear_time_fraction;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 6b — linear-layer share of forward compute",
                   "Fig 6(b), §5.2: non-linear share shrinks with size");
    // (name, d_model, d_ff) from the Qwen2.5 family
    let sizes = [
        ("0.5B", 896usize, 4864usize),
        ("1.5B", 1536, 8960),
        ("3B", 2048, 11008),
        ("7B", 3584, 18944),
        ("14B", 5120, 13824),
    ];
    let mut t = Table::new(&["model", "linear share", "non-linear+attn"]);
    let mut last = 0.0;
    for (name, d, ff) in sizes {
        let f = linear_time_fraction(d, ff, 2048, true);
        t.row(&[
            name.into(),
            format!("{:.1}%", 100.0 * f),
            format!("{:.1}%", 100.0 * (1.0 - f)),
        ]);
        assert!(f >= last * 0.95, "share should grow with size");
        last = f;
    }
    t.print();
    println!("\npaper shape: linear share grows toward ~90%+ at 7B/14B, \
              so INT8-ing non-linear layers (Jetfire) buys little while \
              costing accuracy (Fig 6a)");
}
