//! Table 1: outlier magnitudes at token / channel / other levels for
//! GLU vs non-GLU models.
//!
//! Two sources: (a) the calibrated synthetic activation generator
//! (stands in for Llama/Qwen/OLMo vs GPT2/Pythia — DESIGN.md
//! §Substitutions); (b) real activations captured from in-repo trained
//! tiny GLU / non-GLU models through the `act_*` artifacts.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::model::Method;
use dbfq::outlier::{outlier_stats, ActivationModel};
use dbfq::runtime::Value;
use dbfq::util::bench::Table;
use dbfq::util::Mat;

fn main() {
    common::banner("Table 1 — outlier magnitude by structure",
                   "Table 1, §4.1: GLU outliers are 1-2 orders larger; \
                    occasional ('Others') rival structured ones");

    let mut t = Table::new(&["model", "token-wise", "channel-wise",
                             "others"]);
    for (name, m) in [
        ("synthetic GLU (Llama/Qwen-like)",
         ActivationModel::glu_llm(1024, 2048)),
        ("synthetic non-GLU (GPT2-like)",
         ActivationModel::non_glu_llm(1024, 2048)),
    ] {
        let s = outlier_stats(&m.sample(31));
        t.row(&[
            name.into(),
            format!("{:.1}", s.token_wise),
            format!("{:.1}", s.channel_wise),
            format!("{:.1}", s.others),
        ]);
    }

    // Real in-repo models: train tiny GLU + non-GLU briefly, capture the
    // last layer's GLU/GELU output via act_* artifacts.
    let rt = common::runtime();
    let steps = common::bench_steps(40);
    for (profile, label) in [("tiny", "trained tiny GLU"),
                             ("tiny_nonglu", "trained tiny non-GLU")] {
        if !rt.has_artifact(&format!("act_{profile}")) {
            continue;
        }
        let tr = common::trained(&rt, profile, Method::Bf16, steps, 5);
        let prof = rt.profile(profile).unwrap().clone();
        let corpus =
            dbfq::data::Corpus::synthetic(50_000, prof.vocab, 77);
        let toks = corpus.eval_batches(prof.batch, prof.seq_len, 1)
            .remove(0);
        let out = rt
            .call(
                &format!("act_{profile}"),
                &[
                    Value::vec_f32(tr.params.clone()),
                    Value::mat_i32(toks, prof.batch, prof.seq_len + 1),
                    Value::vec_f32(tr.controller.thresholds.clone()),
                    Value::vec_f32(QScalars::default().to_vec()),
                ],
            )
            .unwrap();
        let act = out[0].as_f32().unwrap();
        let rows = prof.batch * prof.seq_len;
        let cols = act.len() / rows;
        let m = Mat::from_vec(rows, cols, act.to_vec());
        let s = outlier_stats(&m);
        t.row(&[
            label.into(),
            format!("{:.2}", s.token_wise),
            format!("{:.2}", s.channel_wise),
            format!("{:.2}", s.others),
        ]);
    }
    t.print();
    println!("\npaper shape: GLU rows dominate every column; \
              'Others' ≈ channel-wise for GLU (P2). Tiny in-repo models \
              show the same ordering at smaller magnitudes (few training \
              steps).");
}
