//! Fig 2: GLU vs non-GLU activation distributions — (a) input value
//! histogram, (b) sorted magnitude profile, (c) large-entry share.

#[path = "common.rs"]
mod common;

use dbfq::outlier::ActivationModel;
use dbfq::util::bench::Table;
use dbfq::util::Mat;

fn sorted_mag_profile(m: &Mat, quantiles: &[f64]) -> Vec<f32> {
    let mut mags: Vec<f32> = m.data.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantiles
        .iter()
        .map(|q| mags[((mags.len() - 1) as f64 * q) as usize])
        .collect()
}

fn main() {
    common::banner("Fig 2 — GLU vs non-GLU activation distribution",
                   "Fig 2, §4.1: GLU widens the tails dramatically");
    let glu = ActivationModel::glu_llm(1024, 2048).sample(41);
    let non = ActivationModel::non_glu_llm(1024, 2048).sample(42);

    let qs = [0.5, 0.9, 0.99, 0.999, 0.9999, 1.0];
    let pg = sorted_mag_profile(&glu, &qs);
    let pn = sorted_mag_profile(&non, &qs);
    let mut t = Table::new(&["quantile |x|", "GLU", "non-GLU",
                             "GLU/non"]);
    for (i, q) in qs.iter().enumerate() {
        t.row(&[
            format!("{q}"),
            format!("{:.2}", pg[i]),
            format!("{:.2}", pn[i]),
            format!("{:.1}x", pg[i] / pn[i].max(1e-6)),
        ]);
    }
    t.print();

    // Fig 2(b): the "sorted magnitude" elbow — how many entries carry
    // most of the mass.
    let share = |m: &Mat, top_frac: f64| {
        let mut mags: Vec<f64> =
            m.data.iter().map(|v| v.abs() as f64).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let k = ((mags.len() as f64) * top_frac).ceil() as usize;
        let top: f64 = mags[..k].iter().sum();
        let tot: f64 = mags.iter().sum();
        top / tot
    };
    println!("\nL1-mass carried by top 0.1% of entries:");
    println!("  GLU     : {:.1}%", 100.0 * share(&glu, 0.001));
    println!("  non-GLU : {:.1}%", 100.0 * share(&non, 0.001));
    println!("\npaper shape: GLU tails are an order of magnitude wider \
              and a tiny fraction of entries dominates the mass — the \
              case for block-level (not token/channel) mixed precision");
}
