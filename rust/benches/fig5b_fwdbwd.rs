//! Fig 5(b): applying fallback to X in the forward pass only vs in both
//! passes (16-bit activation context) — the paper finds no significant
//! difference, so the INT8 stochastic context wins on memory.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::QScalars;
use dbfq::util::bench::Table;

fn main() {
    common::banner("Fig 5b — fallback in fwd only vs fwd+bwd",
                   "Fig 5(b), §5.1: stochastic INT8 context ≈ 16-bit \
                    fallback context");
    let rt = common::runtime();
    let probe = common::Probe::new(&rt, "probe", 7);
    let gref = probe.reference_grads();

    let mut t = Table::new(&["rate", "fwd-only CosSim", "fwd+bwd CosSim",
                             "gap"]);
    for rate in [0.05f64, 0.1, 0.2, 0.4] {
        let qs = QScalars::default();
        let theta = probe.theta_for_rate(&qs, rate);
        // average CosSim over a few SR seeds (SR makes single draws noisy)
        let mut c_fwd = 0.0;
        let mut c_both = 0.0;
        let seeds = 3;
        for s in 0..seeds {
            let qs_fwd = QScalars { fallback_bwd: 0.0,
                                    ..QScalars::default() };
            let (_, g1, _) = probe.grads(&qs_fwd, theta, 100 + s);
            c_fwd += common::cos(&g1, &gref);
            let qs_both = QScalars { fallback_bwd: 1.0,
                                     ..QScalars::default() };
            let (_, g2, _) = probe.grads(&qs_both, theta, 100 + s);
            c_both += common::cos(&g2, &gref);
        }
        c_fwd /= seeds as f64;
        c_both /= seeds as f64;
        t.row(&[
            format!("{rate:.2}"),
            format!("{c_fwd:.5}"),
            format!("{c_both:.5}"),
            format!("{:+.5}", c_both - c_fwd),
        ]);
    }
    t.print();
    println!("\npaper shape: the gap is negligible -> store pure INT8 \
              stochastic context (halves activation memory for X)");
}
