//! Fig 1(b): INT8 GEMM throughput vs quantization group size K.
//!
//! Two axes (DESIGN.md §Substitutions):
//!   measured — the Rust CPU INT8 blocked GEMM, which exhibits the same
//!              cost structure (per-group dequant overhead shrinks as
//!              the group grows);
//!   modeled  — the RTX 4090 roofline at the paper's sizes, which should
//!              pass near 270 Tops @ 32 and 425 Tops @ 128.

#[path = "common.rs"]
mod common;

use dbfq::costmodel::rtx4090;
use dbfq::gemm;
use dbfq::quant::{block_quant, Rounding, INT8_LEVELS};
use dbfq::util::bench::{bench, gops, Table};
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

fn main() {
    common::banner("Fig 1b — throughput vs group size K",
                   "Fig 1(b), §3.2: 32x32 is 38% slower than 128x128");

    // Measured on CPU (sizes scaled to the testbed).
    let mut t = Table::new(&["dim", "group", "Gops(cpu)", "vs f32"]);
    for dim in [512usize, 1024] {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(dim, dim, 1.0, &mut rng);
        let b = Mat::randn(dim, dim, 1.0, &mut rng);
        let s_f32 = bench(|| {
            std::hint::black_box(gemm::matmul(&a, &b, 1));
        }, 300);
        let f32_gops = gops(dim, dim, dim, s_f32.median_secs());
        for group in [16usize, 32, 64, 128] {
            let qa = block_quant(&a, group, INT8_LEVELS, Rounding::Nearest);
            let qb = block_quant(&b, group, INT8_LEVELS, Rounding::Nearest);
            let s = bench(|| {
                std::hint::black_box(gemm::block_gemm(&qa, &qb, 1));
            }, 300);
            let g = gops(dim, dim, dim, s.median_secs());
            t.row(&[
                dim.to_string(),
                group.to_string(),
                format!("{g:.2}"),
                format!("{:.2}x", g / f32_gops),
            ]);
        }
    }
    t.print();

    // Modeled on RTX 4090 at the paper's GEMM dims.
    let g4090 = rtx4090();
    let mut t2 = Table::new(&["dim", "K=32", "K=64", "K=128", "K=256"]);
    for dim in [2048usize, 4096, 8192] {
        let row: Vec<String> = [32usize, 64, 128, 256]
            .iter()
            .map(|&kg| {
                format!("{:.0}", g4090.int8_gemm_tops(dim, dim, dim, kg,
                                                      0.0))
            })
            .collect();
        t2.row(&[dim.to_string(), row[0].clone(), row[1].clone(),
                 row[2].clone(), row[3].clone()]);
    }
    println!("\nRTX4090 roofline (Tops; paper: ~270 @32, ~425 @128):");
    t2.print();
}
