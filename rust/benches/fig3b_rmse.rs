//! Fig 3(b): RMSE of Fallback vs "Double Bit" (INT16) block quantization
//! as outlier magnitude grows.

#[path = "common.rs"]
mod common;

use dbfq::quant::{self, metrics, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;
use dbfq::util::Mat;

fn activation(mag: f32, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::randn(256, 256, 1.0, &mut rng);
    for _ in 0..12 {
        let i = rng.below(m.data.len());
        m.data[i] = mag * (1.0 + rng.uniform_f32())
            * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    m
}

fn main() {
    common::banner("Fig 3b — fallback vs INT16 double-bit RMSE",
                   "Fig 3(b), §4.3: fallback wins once outliers exist \
                    (even at 20000 magnitude)");
    let mut t = Table::new(&["outlier-mag", "INT8", "INT16",
                             "Fallback(2xINT8)", "fb/int16"]);
    for mag in [0.0f32, 10.0, 100.0, 1000.0, 20000.0] {
        let x = activation(mag, 11 + mag as u64);
        let e8 = metrics::rmse(
            &quant::block_quant(&x, 128, INT8_LEVELS, Rounding::Nearest)
                .dequant().data,
            &x.data);
        let e16 = metrics::rmse(
            &quant::int16_block_quant(&x, 128).dequant().data, &x.data);
        let fq = quant::fallback_quant(&x, -1.0, 128, INT8_LEVELS,
                                       Criterion::AbsMax);
        let efb = metrics::rmse(&fq.dequant().data, &x.data);
        t.row(&[
            format!("{mag:.0}"),
            format!("{e8:.6}"),
            format!("{e16:.6}"),
            format!("{efb:.6}"),
            format!("{:.2}", efb / e16),
        ]);
    }
    t.print();
    println!("\npaper shape: fallback < INT16 whenever outliers make \
              the in-block distribution heavy-tailed (fb/int16 < 1)");
}
