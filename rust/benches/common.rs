//! Shared helpers for the bench binaries (each bench target includes
//! this via `#[path = "common.rs"] mod common;`).

#![allow(dead_code)]

use dbfq::coordinator::{TrainConfig, Trainer};
use dbfq::data::Corpus;
use dbfq::model::Method;
use dbfq::runtime::Runtime;
use dbfq::util::rng::Pcg64;

/// Benches honor DBFQ_BENCH_STEPS to scale training-heavy benches.
pub fn bench_steps(default: usize) -> usize {
    std::env::var("DBFQ_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn runtime() -> Runtime {
    Runtime::open(&dbfq::runtime::artifacts_dir())
        .expect("run `make artifacts` first")
}

/// Train (or load a cached checkpoint of) a model for bench evals.
/// Cache key: profile + method + steps. Returns the trainer.
pub fn trained<'rt>(
    rt: &'rt Runtime,
    profile: &str,
    method: Method,
    steps: usize,
    seed: u64,
) -> Trainer<'rt> {
    let prof = rt.profile(profile).unwrap().clone();
    let mut cfg = TrainConfig::new(profile, method, seed, steps);
    cfg.lr.peak = 1e-3;
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let cache = format!(
        "runs/bench_ckpt_{profile}_{}_{steps}_{seed}",
        method.tag()
    );
    std::fs::create_dir_all("runs").ok();
    if tr.load_checkpoint(&cache).is_ok() {
        return tr;
    }
    let corpus = Corpus::synthetic(200_000, prof.vocab, 55);
    let mut rng = Pcg64::new(seed);
    for _ in 0..steps {
        let toks = corpus.sample_batch(prof.batch, prof.seq_len, &mut rng);
        tr.step_on(&toks).unwrap();
    }
    tr.save_checkpoint(&cache).ok();
    tr
}

/// Mean cosine similarity between two flat gradient vectors.
pub fn cos(a: &[f32], b: &[f32]) -> f64 {
    dbfq::quant::metrics::cosine_similarity(a, b)
}

pub fn banner(name: &str, paper: &str) {
    println!("\n================================================");
    println!("{name}");
    println!("paper reference: {paper}");
    println!("================================================");
}

/// Inject trained-LLM outlier structure (§4.1): scale a sparse set of
/// gate/up-projection output rows so GLU activations get hot channels.
/// Randomly initialized / briefly-trained models have no outliers; this
/// stands in for the structure trillions of tokens create (DESIGN.md
/// §Substitutions).
pub fn inject_outliers(params: &mut [f32],
                       prof: &dbfq::runtime::ProfileMeta) {
    for leaf in &prof.param_layout {
        if !leaf.name.ends_with("win") {
            continue;
        }
        let (l_dim, rows, cols) =
            (leaf.shape[0], leaf.shape[1], leaf.shape[2]);
        for l in 0..l_dim {
            for t in 0..(rows / 48).max(1) {
                let j = (l * 37 + t * 97 + 11) % rows;
                let base = leaf.offset + (l * rows + j) * cols;
                for v in &mut params[base..base + cols] {
                    *v *= 6.0;
                }
            }
        }
    }
}

/// Helper around the `grads_<profile>_fallback` probe artifact: run it
/// with given qscalars + per-site theta, return (loss, grads, rates).
pub struct Probe<'rt> {
    pub rt: &'rt Runtime,
    pub profile: String,
    pub params: Vec<f32>,
    pub tokens: Vec<i32>,
    pub n_sites: usize,
}

impl<'rt> Probe<'rt> {
    pub fn new(rt: &'rt Runtime, profile: &str, seed: u64) -> Probe<'rt> {
        let prof = rt.profile(profile).unwrap().clone();
        let mut params = rt
            .call(&format!("init_{profile}"),
                  &[dbfq::runtime::Value::scalar_i32(seed as i32)])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        inject_outliers(&mut params, &prof);
        let corpus = Corpus::synthetic(50_000, prof.vocab, seed ^ 0xAB);
        let mut rng = Pcg64::new(seed);
        let tokens = corpus.sample_batch(prof.batch, prof.seq_len,
                                         &mut rng);
        Probe { rt, profile: profile.to_string(), params, tokens,
                n_sites: prof.n_sites }
    }

    pub fn grads(&self, qs: &dbfq::coordinator::QScalars, theta: f32,
                 seed: i32) -> (f64, Vec<f32>, Vec<f32>) {
        let prof = self.rt.profile(&self.profile).unwrap();
        let out = self
            .rt
            .call(
                &format!("grads_{}_fallback", self.profile),
                &[
                    dbfq::runtime::Value::vec_f32(self.params.clone()),
                    dbfq::runtime::Value::mat_i32(
                        self.tokens.clone(), prof.batch,
                        prof.seq_len + 1),
                    dbfq::runtime::Value::scalar_i32(seed),
                    dbfq::runtime::Value::vec_f32(
                        vec![theta; self.n_sites]),
                    dbfq::runtime::Value::vec_f32(qs.to_vec()),
                ],
            )
            .unwrap();
        let loss = out[0].scalar().unwrap() as f64;
        let grads = out[1].clone().into_f32().unwrap();
        let rates = out[2].clone().into_f32().unwrap();
        (loss, grads, rates)
    }

    /// Bisection on theta until the mean fallback rate hits `target`.
    pub fn theta_for_rate(&self, qs: &dbfq::coordinator::QScalars,
                          target: f64) -> f32 {
        // expand hi until the rate drops below target (L1 metrics can
        // be in the hundreds), then bisect
        let (mut lo, mut hi) = (0.0f32, 64.0f32);
        for _ in 0..8 {
            let (_, _, rates) = self.grads(qs, hi, 1);
            let rate = rates.iter().map(|&r| r as f64).sum::<f64>()
                / rates.len() as f64;
            if rate <= target {
                break;
            }
            lo = hi;
            hi *= 8.0;
        }
        for _ in 0..18 {
            let mid = 0.5 * (lo + hi);
            let (_, _, rates) = self.grads(qs, mid, 1);
            let rate = rates.iter().map(|&r| r as f64).sum::<f64>()
                / rates.len() as f64;
            if rate > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Reference (effectively unquantized) gradients.
    pub fn reference_grads(&self) -> Vec<f32> {
        let qs = dbfq::coordinator::QScalars::lossless();
        self.grads(&qs, f32::INFINITY, 1).1
    }
}
