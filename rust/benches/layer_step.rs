//! Layer-step pipeline: cached GemmPlans across training microsteps.
//!
//! Measures the tentpole claim of the plan cache — that planning once
//! and executing many times beats re-quantizing/repacking weights per
//! call — on the four linear sites of one transformer layer
//! (`model::layer_linears`), each running fwd + dX + dW per
//! microstep through the fallback GEMM engine:
//!
//! * `cached`   — one `LayerStep`, warm `PlanCache`: from the 2nd
//!                microstep on, every weight lookup hits and the only
//!                per-call quantization is the activation/gradient
//!                side.
//! * `uncached` — the same driver with the cache cleared before
//!                every microstep: both weight halves re-quantize and
//!                repack per site per microstep (the pre-pipeline
//!                behaviour).
//!
//! Both loops run through `microstep_in_place`, the zero-allocation
//! steady-state path that reuses the driver's output arena and the
//! persistent worker pool (`util::pool`).
//!
//! Emits `BENCH_layer_step.json` (schema in `docs/BENCHMARKS.md`)
//! with per-microstep times, cached-vs-uncached Gops, per-microstep
//! cache hit rates (must be 1.0 from the 2nd microstep on), the
//! executed per-site fallback rates, the quant-work counter deltas,
//! and the cost model's step-level projection from the measured
//! `SubstrateCalibration`. Set `BENCH_SMOKE=1` for a seconds-long CI
//! smoke run.

use std::time::Instant;

use dbfq::costmodel::{rtx4090, SubstrateCalibration};
use dbfq::gemm::{kernels, LayerStep, LayerStepConfig};
use dbfq::quant::{fallback_quant, quant_work_counters,
                  theta_for_rate, Criterion, INT8_LEVELS};
use dbfq::util::bench::Table;
use dbfq::util::json::{obj, Json};
use dbfq::util::threadpool::default_threads;

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (d_model, d_ff, tokens, block, microsteps) = if smoke {
        (64usize, 128usize, 64usize, 32usize, 4usize)
    } else {
        (256, 1024, 512, 128, 8)
    };
    let threads = default_threads().max(2);
    let mut cfg = LayerStepConfig::new(d_model, d_ff, tokens, block);
    cfg.glu = false; // GPT-2-style 4d MLP, as in Table 3
    cfg.threads = threads;

    println!("\n================================================");
    println!(
        "layer-step pipeline: d={d_model} ff={d_ff} tokens={tokens} \
         block={block}, {threads} threads, {microsteps} microsteps"
    );
    println!("================================================");

    let mut ls = LayerStep::with_random_weights(cfg.clone(), 0xBEEF);
    let sites: Vec<_> = ls.sites().to_vec();
    let (acts, grads) =
        dbfq::gemm::synth_microbatch(&sites, 0x5EED, 200.0);
    // Pin θ per site from an offline probe at the paper's band
    // midpoint; the controller takes over at the step boundary.
    let thetas: Vec<f32> = acts
        .iter()
        .map(|x| {
            let probe = fallback_quant(x, f32::INFINITY, block,
                                       INT8_LEVELS,
                                       Criterion::AbsMax);
            theta_for_rate(&probe.metric, 0.2)
        })
        .collect();
    ls.controller_mut().thresholds.copy_from_slice(&thetas);

    let flops = sites
        .iter()
        .map(|l| l.microstep_flops())
        .sum::<f64>();

    // -- uncached baseline: weight halves rebuilt every microstep ----
    let (qu0, pu0) = quant_work_counters();
    let mut uncached_ms = Vec::with_capacity(microsteps);
    for _ in 0..microsteps {
        ls.clear_cache();
        let t = Instant::now();
        ls.microstep_in_place(&acts, &grads);
        std::hint::black_box(ls.outputs());
        uncached_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let (qu1, pu1) = quant_work_counters();
    // drain the rate accumulator so `applied_rates` below reflects
    // the cached phase only, not a mix of both measurement runs —
    // and re-pin θ, since end_step may have adjusted it, so both
    // phases execute at identical thresholds
    let _ = ls.end_step();
    ls.controller_mut().thresholds.copy_from_slice(&thetas);

    // -- cached pipeline: plan once, execute many --------------------
    ls.clear_cache();
    let (qc0, pc0) = quant_work_counters();
    let mut cached_ms = Vec::with_capacity(microsteps);
    let mut per_microstep = Vec::new();
    let mut rates = Vec::new();
    for s in 0..microsteps {
        let t = Instant::now();
        let rep = ls.microstep_in_place(&acts, &grads);
        std::hint::black_box(ls.outputs());
        cached_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let lookups = rep.cache_hits + rep.cache_misses;
        per_microstep.push((rep.cache_hits, rep.cache_misses));
        if s + 1 == microsteps {
            rates = rep
                .sites
                .iter()
                .map(|sr| (sr.name, sr.fallback_rate,
                           sr.bwd_fallback_rate))
                .collect();
        }
        assert_eq!(lookups as usize, 2 * sites.len());
    }
    let (qc1, pc1) = quant_work_counters();
    let applied = ls.end_step();

    let cached_steady = median(&cached_ms[1..]);
    let uncached_steady = median(&uncached_ms);
    let cached_gops = flops / (cached_steady / 1e3) / 1e9;
    let uncached_gops = flops / (uncached_steady / 1e3) / 1e9;
    let speedup = uncached_steady / cached_steady;
    let warm_hit_rate: f64 = {
        let (h, m) = per_microstep[1..].iter().fold(
            (0u64, 0u64),
            |(h, m), &(hh, mm)| (h + hh, m + mm),
        );
        h as f64 / (h + m).max(1) as f64
    };

    let mut table = Table::new(&["run", "first ms", "steady ms",
                                 "Gops", "hit rate 2nd+"]);
    table.row(&[
        "uncached".into(),
        format!("{:.1}", uncached_ms[0]),
        format!("{uncached_steady:.1}"),
        format!("{uncached_gops:.2}"),
        "-".into(),
    ]);
    table.row(&[
        "cached".into(),
        format!("{:.1}", cached_ms[0]),
        format!("{cached_steady:.1}"),
        format!("{cached_gops:.2}"),
        format!("{warm_hit_rate:.2}"),
    ]);
    table.print();
    println!(
        "\ncached vs uncached steady-state: {speedup:.2}x \
         (target > 1.0x); warm hit rate {warm_hit_rate:.2} \
         (target 1.00)"
    );
    println!(
        "quant calls / panel packs per run: uncached {}/{}, \
         cached {}/{}",
        qu1 - qu0, pu1 - pu0, qc1 - qc0, pc1 - pc0
    );
    println!(
        "executed fallback rates: {rates:?}; controller applied \
         {applied:?}"
    );

    // -- step-level cost projection from measured calibration --------
    let cal_dim = if smoke { 96 } else { 256 };
    let cal_block = block.min(cal_dim);
    let cal = SubstrateCalibration::measure(cal_dim, cal_block,
                                            threads);
    let mean_rate = rates.iter().map(|&(_, r, _)| r).sum::<f64>()
        / rates.len().max(1) as f64;
    let sub_ms = cal.substrate_layer_step_secs(
        d_model, d_ff, cfg.glu, tokens, mean_rate) * 1e3;
    let g4090 = rtx4090();
    let proj_ms = cal.projected_layer_step_secs(
        &g4090, d_model, d_ff, cfg.glu, tokens, mean_rate) * 1e3;
    println!(
        "\ncost model: substrate estimate {sub_ms:.1} ms/microstep \
         (measured {cached_steady:.1} ms), 4090 projection \
         {proj_ms:.3} ms"
    );

    let report = obj(vec![
        ("bench", Json::Str("layer_step".into())),
        ("smoke", Json::Bool(smoke)),
        ("config", obj(vec![
            ("d_model", Json::Num(d_model as f64)),
            ("d_ff", Json::Num(d_ff as f64)),
            ("glu", Json::Bool(cfg.glu)),
            ("tokens", Json::Num(tokens as f64)),
            ("block", Json::Num(block as f64)),
            ("threads", Json::Num(threads as f64)),
            ("microsteps", Json::Num(microsteps as f64)),
            ("data_path",
             Json::Str(format!("{:?}", cfg.path))),
            ("kernel_backend",
             Json::Str(ls.kernel_backend().into())),
        ])),
        ("cpu_features",
         Json::Arr(kernels::cpu_features()
             .iter()
             .map(|&f| Json::Str(f.into()))
             .collect())),
        ("sites", Json::Arr(
            sites
                .iter()
                .map(|l| obj(vec![
                    ("name", Json::Str(l.name.into())),
                    ("m", Json::Num(l.m as f64)),
                    ("n", Json::Num(l.n as f64)),
                    ("k", Json::Num(l.k as f64)),
                    ("microstep_flops",
                     Json::Num(l.microstep_flops())),
                ]))
                .collect(),
        )),
        ("flops_per_microstep", Json::Num(flops)),
        ("cached", obj(vec![
            ("per_microstep_ms", Json::Arr(
                cached_ms.iter().map(|&x| Json::Num(x)).collect())),
            ("first_ms", Json::Num(cached_ms[0])),
            ("steady_ms", Json::Num(cached_steady)),
            ("gops", Json::Num(cached_gops)),
            ("quant_calls", Json::Num((qc1 - qc0) as f64)),
            ("panel_packs", Json::Num((pc1 - pc0) as f64)),
        ])),
        ("uncached", obj(vec![
            ("per_microstep_ms", Json::Arr(
                uncached_ms.iter().map(|&x| Json::Num(x)).collect())),
            ("steady_ms", Json::Num(uncached_steady)),
            ("gops", Json::Num(uncached_gops)),
            ("quant_calls", Json::Num((qu1 - qu0) as f64)),
            ("panel_packs", Json::Num((pu1 - pu0) as f64)),
        ])),
        ("cache", obj(vec![
            ("capacity",
             Json::Num(ls.cache().capacity() as f64)),
            ("entries", Json::Num(ls.cache().len() as f64)),
            ("warm_hit_rate", Json::Num(warm_hit_rate)),
            ("per_microstep", Json::Arr(
                per_microstep
                    .iter()
                    .map(|&(h, m)| obj(vec![
                        ("hits", Json::Num(h as f64)),
                        ("misses", Json::Num(m as f64)),
                    ]))
                    .collect(),
            )),
        ])),
        ("fallback", obj(vec![
            ("per_site", Json::Arr(
                rates
                    .iter()
                    .map(|&(name, r, bwd)| obj(vec![
                        ("name", Json::Str(name.into())),
                        ("rate", Json::Num(r)),
                        ("bwd_rate", Json::Num(bwd)),
                    ]))
                    .collect(),
            )),
            ("mean_rate", Json::Num(mean_rate)),
            ("applied_rates", Json::Arr(
                applied
                    .iter()
                    .map(|&r| Json::Num(r as f64))
                    .collect(),
            )),
        ])),
        ("criteria", obj(vec![
            ("cached_vs_uncached", Json::Num(speedup)),
            ("warm_hit_rate", Json::Num(warm_hit_rate)),
        ])),
        ("projection", obj(vec![
            ("substrate_ms", Json::Num(sub_ms)),
            ("rtx4090_ms", Json::Num(proj_ms)),
            ("calibration_int8_gops",
             Json::Num(cal.int8_gops)),
            ("calibration_backend",
             Json::Str(cal.backend.into())),
        ])),
    ]);
    std::fs::write("BENCH_layer_step.json", report.to_string())
        .expect("write BENCH_layer_step.json");
    println!("\nwrote BENCH_layer_step.json");
}
