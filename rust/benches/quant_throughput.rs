//! Quantization construction throughput — measures the parallel
//! `BlockQuant` / `FallbackQuant` builders (block rows distributed via
//! `threadpool::parallel_items`, per-block stochastic-rounding RNG
//! streams). Quantization runs once per activation per step, so its
//! scaling is part of the end-to-end story, not just the GEMMs'.
//!
//! Emits `BENCH_quant_throughput.json` with Melem/s per (op, rounding,
//! threads) and the N-thread:1-thread speedup. Set `BENCH_SMOKE=1` for
//! a seconds-long CI smoke run.

use dbfq::gemm::kernels;
use dbfq::quant::{self, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::{bench, Table};
use dbfq::util::json::{obj, Json};
use dbfq::util::rng::Pcg64;
use dbfq::util::threadpool::default_threads;
use dbfq::util::Mat;

const BLOCK: usize = 128;

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let dim: usize = if smoke { 256 } else { 2048 };
    let target_ms: u64 = if smoke { 20 } else { 150 };

    println!("\n================================================");
    println!("Quantization throughput ({dim}x{dim}, block {BLOCK})");
    println!("================================================");

    let nthreads = default_threads().max(2);
    let thread_counts = [1usize, nthreads];
    let mut rng = Pcg64::new(0x0A17);
    let x = Mat::randn(dim, dim, 1.0, &mut rng);
    let melems = (dim * dim) as f64 / 1e6;

    let mut table =
        Table::new(&["op", "rounding", "thr", "Melem/s", "speedup"]);
    let mut rows = Vec::new();
    let mut record = |table: &mut Table, op: &str, rnd: &str,
                      threads: usize, rate: f64, base_1t: f64| {
        table.row(&[
            op.into(), rnd.into(), threads.to_string(),
            format!("{rate:.1}"),
            if threads == 1 {
                "-".into()
            } else {
                format!("{:.2}x", rate / base_1t)
            },
        ]);
        rows.push(obj(vec![
            ("op", Json::Str(op.into())),
            ("rounding", Json::Str(rnd.into())),
            ("threads", Json::Num(threads as f64)),
            ("melems_per_sec", Json::Num(rate)),
        ]));
    };

    for (rnd, rounding) in [("nearest", Rounding::Nearest),
                            ("stochastic", Rounding::Stochastic(7))] {
        let mut base_1t = 0.0;
        for &threads in &thread_counts {
            let s = bench(|| {
                std::hint::black_box(quant::block_quant_threads(
                    &x, BLOCK, INT8_LEVELS, rounding, threads));
            }, target_ms);
            let rate = melems / s.median_secs();
            if threads == 1 {
                base_1t = rate;
            }
            record(&mut table, "block_quant", rnd, threads, rate,
                   base_1t);
        }
    }

    // fallback: residual pass always runs over every block (the
    // u-mask only gates GEMM-time work), so theta choice is not a
    // cost knob here — use the paper-ish AbsMax criterion.
    let mut base_1t = 0.0;
    for &threads in &thread_counts {
        let s = bench(|| {
            std::hint::black_box(quant::fallback_quant_threads(
                &x, 50.0, BLOCK, INT8_LEVELS, Criterion::AbsMax,
                threads));
        }, target_ms);
        let rate = melems / s.median_secs();
        if threads == 1 {
            base_1t = rate;
        }
        record(&mut table, "fallback_quant", "nearest", threads, rate,
               base_1t);
    }

    // the permuted-transpose reuse (pipeline dW path): what replacing
    // a full re-quantization of xᵀ actually costs per microstep
    let fx = quant::fallback_quant_threads(&x, 50.0, BLOCK,
                                           INT8_LEVELS,
                                           Criterion::AbsMax,
                                           nthreads);
    let s = bench(|| {
        std::hint::black_box(fx.transposed());
    }, target_ms);
    let rate = melems / s.median_secs();
    record(&mut table, "fallback_transposed", "-", 1, rate, rate);
    table.print();

    let report = obj(vec![
        ("bench", Json::Str("quant_throughput".into())),
        ("smoke", Json::Bool(smoke)),
        ("dims", obj(vec![
            ("rows", Json::Num(dim as f64)),
            ("cols", Json::Num(dim as f64)),
            ("block", Json::Num(BLOCK as f64)),
        ])),
        ("threads_max", Json::Num(nthreads as f64)),
        // Quantization itself is kernel-agnostic, but the selected
        // GEMM backend + detected features are recorded here too so
        // every BENCH_*.json from one run names the same substrate.
        ("kernel_backend", Json::Str(kernels::select().name.into())),
        ("cpu_features",
         Json::Arr(kernels::cpu_features()
             .iter()
             .map(|&f| Json::Str(f.into()))
             .collect())),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_quant_throughput.json", report.to_string())
        .expect("write BENCH_quant_throughput.json");
    println!("\nwrote BENCH_quant_throughput.json");
}
