//! Whole-model pipeline: N transformer layers + LM head sharing one
//! plan cache, with warm-state persistence.
//!
//! Measures the `ModelStep` tentpole claims on a 4-layer model:
//!
//! * `cold`          — the cache is cleared before every microstep:
//!                     every weight half re-quantizes and repacks
//!                     (the pre-pipeline behaviour, now × 4 layers).
//! * `cached`        — one `ModelStep`, warm shared cache: from the
//!                     2nd microstep on, every lookup of every layer
//!                     *and the (d_model × vocab) LM head* hits.
//! * `warm_restored` — a fresh driver rebuilt from the warm-state
//!                     JSON (`ModelStep::from_warm_state`): the
//!                     *first* microstep already runs at hit rate
//!                     1.0 and is bit-identical to the microstep the
//!                     saved driver runs next.
//!
//! Also checks, per host kernel backend, that one cold ModelStep
//! microstep is bit-identical to composed per-layer `LayerStep`s
//! plus a direct engine computation of the head.
//!
//! The timed loops run through `microstep_in_place` — the PR 7
//! zero-allocation steady-state path that reuses the driver's output
//! arena — and a dispatch-overhead phase re-times the warm microstep
//! with the persistent worker pool force-disabled (per-call scoped
//! threads), recording the pool's latency win plus the runtime work
//! counters (`dispatch_overhead` fields: steady-state thread spawns
//! and workspace growths per microstep, expected 0 when pooled).
//! A shard-scaling phase re-times the warm microstep at S = 1 / 2 /
//! auto (`PALLAS_SHARDS`) — the `shard_scaling` fields and criterion
//! (S=auto over S=1 warm throughput; sharding is bit-neutral).
//!
//! Emits `BENCH_model_step.json` (schema in `docs/BENCHMARKS.md`).
//! Set `BENCH_SMOKE=1` for a seconds-long CI smoke run.

use std::time::Instant;

use dbfq::costmodel::{rtx4090, SubstrateCalibration};
use dbfq::gemm::{grad_sr_seed, kernels, layer_sr_seed,
                 site_reference, synth_microbatch, Kernels,
                 LayerStep, ModelStep, ModelStepConfig, SiteOutputs};
use dbfq::model::{model_linears, sites_per_layer, LinearShape};
use dbfq::quant::{fallback_quant, quant_work_counters,
                  theta_for_rate, Criterion, Rounding, INT8_LEVELS};
use dbfq::util::bench::Table;
use dbfq::util::json::{obj, Json};
use dbfq::util::pool;
use dbfq::util::rng::Pcg64;
use dbfq::util::threadpool::default_threads;
use dbfq::util::Mat;

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

/// The LM head's three GEMMs through the shared cache-free
/// [`site_reference`] — the composition reference for the head site
/// (its SR stream is "layer `layers`", site 0 of that stream), the
/// same helper `tests/model_step_prop.rs` uses.
fn head_reference(cfg: &ModelStepConfig, l: &LinearShape, w: &Mat,
                  x: &Mat, dy: &Mat, theta: f32, t: usize,
                  kn: &'static Kernels) -> SiteOutputs {
    let sr = Rounding::Stochastic(grad_sr_seed(
        layer_sr_seed(cfg.sr_seed, cfg.layers), t, 0));
    site_reference(l, w, x, dy, theta, sr, cfg.block, cfg.threads,
                   cfg.path, kn)
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // ≥ 4 layers + LM head in both modes: the multi-layer cache
    // pressure is the thing under test, only the dims shrink.
    let (layers, d_model, d_ff, vocab, tokens, block, microsteps) =
        if smoke {
            (4usize, 32usize, 64usize, 96usize, 32usize, 16usize,
             3usize)
        } else {
            (4, 128, 512, 1024, 128, 32, 6)
        };
    let threads = default_threads().max(2);
    let mut cfg =
        ModelStepConfig::new(layers, d_model, d_ff, vocab, tokens,
                             block);
    cfg.glu = false; // GPT-2-style 4d MLP, as in Table 3
    cfg.threads = threads;
    let n_sites = cfg.n_sites();

    println!("\n================================================");
    println!(
        "model-step pipeline: {layers} layers + lm_head, d={d_model} \
         ff={d_ff} vocab={vocab} tokens={tokens} block={block}, \
         {threads} threads, {microsteps} microsteps"
    );
    println!("================================================");

    let sites = model_linears(layers, d_model, d_ff, cfg.glu, vocab,
                              tokens);
    let mut rng = Pcg64::new(0xBEEF);
    let weights: Vec<Mat> = sites
        .iter()
        .map(|l| Mat::randn(l.k, l.n, 0.05, &mut rng))
        .collect();
    let (acts, grads) = synth_microbatch(&sites, 0x5EED, 200.0);
    // Pin θ per site from an offline probe at the paper's band
    // midpoint; the controller takes over at step boundaries.
    let thetas: Vec<f32> = acts
        .iter()
        .map(|x| {
            let probe = fallback_quant(x, f32::INFINITY, block,
                                       INT8_LEVELS,
                                       Criterion::AbsMax);
            theta_for_rate(&probe.metric, 0.2)
        })
        .collect();
    let flops: f64 = sites.iter().map(|l| l.microstep_flops()).sum();

    let mut ms = ModelStep::new(cfg.clone(), weights.clone());
    ms.controller_mut().thresholds.copy_from_slice(&thetas);

    // -- cold baseline: weight halves rebuilt every microstep --------
    let (qc0, pc0) = quant_work_counters();
    let mut cold_ms = Vec::with_capacity(microsteps);
    for _ in 0..microsteps {
        ms.clear_cache();
        let t = Instant::now();
        ms.microstep_in_place(&acts, &grads);
        std::hint::black_box(ms.outputs());
        cold_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let (qc1, pc1) = quant_work_counters();
    // drain the accumulator and re-pin θ so every phase executes at
    // identical thresholds
    let _ = ms.end_step();
    ms.controller_mut().thresholds.copy_from_slice(&thetas);

    // -- cached: one shared cache across layers + head ---------------
    ms.clear_cache();
    let (qw0, pw0) = quant_work_counters();
    let mut cached_ms = Vec::with_capacity(microsteps);
    let mut per_microstep = Vec::new();
    // per-site hit/miss totals over the warm microsteps (2nd+)
    let mut site_hits = vec![0u64; n_sites];
    let mut site_misses = vec![0u64; n_sites];
    let mut last_rep = None;
    for s in 0..microsteps {
        let t = Instant::now();
        let rep = ms.microstep_in_place(&acts, &grads);
        std::hint::black_box(ms.outputs());
        cached_ms.push(t.elapsed().as_secs_f64() * 1e3);
        assert_eq!((rep.cache_hits + rep.cache_misses) as usize,
                   2 * n_sites);
        per_microstep.push((rep.cache_hits, rep.cache_misses));
        if s > 0 {
            for (i, sr) in rep.sites.iter().enumerate() {
                site_hits[i] += sr.cache_hits;
                site_misses[i] += sr.cache_misses;
            }
        }
        last_rep = Some(rep);
    }
    let (qw1, pw1) = quant_work_counters();
    let last_rep = last_rep.unwrap();
    let warm_hit_rate: f64 = {
        let (h, m) = per_microstep[1..].iter().fold(
            (0u64, 0u64),
            |(h, m), &(hh, mm)| (h + hh, m + mm),
        );
        h as f64 / (h + m).max(1) as f64
    };
    assert_eq!(warm_hit_rate, 1.0,
               "every lookup must hit from the 2nd microstep on");
    // step boundary, then re-pin θ so the restored phase runs at the
    // same thresholds (the warm state serializes the controller as
    // it stands — save at a step boundary, after end_step)
    let _ = ms.end_step();
    ms.controller_mut().thresholds.copy_from_slice(&thetas);

    // -- warm state: serialize → restore → first microstep warm -----
    let cal_dim = if smoke { 96 } else { 256 };
    let cal = SubstrateCalibration::measure(cal_dim,
                                            block.min(cal_dim),
                                            threads);
    let state_text = ms.warm_state(Some(&cal)).to_string();
    let parsed = Json::parse(&state_text)
        .expect("warm state must serialize to valid JSON");
    let (mut ms2, cal_restored) =
        ModelStep::from_warm_state(cfg.clone(), weights.clone(),
                                   &parsed)
            .expect("warm-state restore");
    let cal_roundtrip = cal_restored
        .map(|c| c.int8_gops == cal.int8_gops
             && c.fallback == cal.fallback)
        .unwrap_or(false);
    let mut warm_restored_ms = Vec::with_capacity(microsteps);
    let mut first_outs = None;
    let mut first_hit_rate = 0.0;
    for s in 0..microsteps {
        let t = Instant::now();
        let (outs, rep) = ms2.microstep(&acts, &grads);
        warm_restored_ms.push(t.elapsed().as_secs_f64() * 1e3);
        if s == 0 {
            first_hit_rate = rep.cache_hits as f64
                / (rep.cache_hits + rep.cache_misses).max(1) as f64;
            assert_eq!(rep.cache_misses, 0,
                       "restored process must start at steady state");
            first_outs = Some(outs);
        } else {
            std::hint::black_box(outs);
        }
    }
    // bit-identity: the saved driver's next microstep (same index as
    // the restored driver's first) must agree on every output
    let (outs_saved, _) = ms.microstep(&acts, &grads);
    let first_outs = first_outs.unwrap();
    let warm_restored_identical = outs_saved
        .iter()
        .zip(&first_outs)
        .all(|(a, b)| {
            a.y.data == b.y.data
                && a.dx.data == b.dx.data
                && a.dw.data == b.dw.data
        });
    assert!(warm_restored_identical,
            "restored first microstep must be bit-identical to the \
             saved driver's next microstep");

    // -- per-backend: ModelStep ≡ composed LayerSteps + head ---------
    let mut backend_checks = Vec::new();
    for kn in kernels::available() {
        let mut m = ModelStep::new(cfg.clone(), weights.clone())
            .with_kernels(kn);
        m.controller_mut().thresholds.copy_from_slice(&thetas);
        let (mo, _) = m.microstep(&acts, &grads);
        let mut identical = true;
        for l in 0..layers {
            let mut ls = LayerStep::new(
                cfg.layer_config(l),
                weights[4 * l..4 * l + 4].to_vec(),
            )
            .with_kernels(kn);
            ls.controller_mut()
                .thresholds
                .copy_from_slice(&thetas[4 * l..4 * l + 4]);
            let (lo, _) = ls.microstep(&acts[4 * l..4 * l + 4],
                                       &grads[4 * l..4 * l + 4]);
            for (i, b) in lo.iter().enumerate() {
                let a = &mo[4 * l + i];
                identical &= a.y.data == b.y.data
                    && a.dx.data == b.dx.data
                    && a.dw.data == b.dw.data;
            }
        }
        let h = n_sites - 1;
        let ho = head_reference(&cfg, &sites[h], &weights[h],
                                &acts[h], &grads[h], thetas[h], 0,
                                kn);
        identical &= mo[h].y.data == ho.y.data
            && mo[h].dx.data == ho.dx.data
            && mo[h].dw.data == ho.dw.data;
        assert!(identical,
                "ModelStep must match composed LayerSteps on backend \
                 {}", kn.name);
        backend_checks.push((kn.name, identical));
    }

    // -- dispatch overhead: warm microstep, pool vs scoped -----------
    // Same warm driver, same buffers (`microstep_in_place`): the
    // only difference between the two runs is whether the engine
    // dispatches onto the persistent worker pool or spawns a fresh
    // `std::thread::scope` per GEMM. The runtime work counters are
    // sampled alongside: a warm pooled microstep must run with zero
    // thread spawns and zero workspace growths (the hard assertion
    // lives in `tests/pool_prop.rs`; here the rate is recorded).
    let disp_iters = if smoke { 3 } else { 5 };
    pool::set_pool_enabled(true);
    ms.microstep_in_place(&acts, &grads); // settle pool workspaces
    let (ds0, dw0) = pool::work_counters();
    let mut pooled_step_ms = Vec::with_capacity(disp_iters);
    for _ in 0..disp_iters {
        let t = Instant::now();
        ms.microstep_in_place(&acts, &grads);
        pooled_step_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let (ds1, dw1) = pool::work_counters();
    let steady_spawns = (ds1 - ds0) as f64 / disp_iters as f64;
    let steady_ws = (dw1 - dw0) as f64 / disp_iters as f64;
    pool::set_pool_enabled(false);
    ms.microstep_in_place(&acts, &grads);
    let mut scoped_step_ms = Vec::with_capacity(disp_iters);
    for _ in 0..disp_iters {
        let t = Instant::now();
        ms.microstep_in_place(&acts, &grads);
        scoped_step_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    pool::set_pool_enabled(true);
    let pooled_steady = median(&pooled_step_ms);
    let scoped_steady = median(&scoped_step_ms);
    let dispatch_ratio = scoped_steady / pooled_steady.max(1e-9);

    // -- shard scaling: warm microstep at S = 1 / 2 / auto -----------
    // Sharding is bit-neutral (tests/shard_prop.rs), so this phase is
    // pure perf trajectory: a fresh warm driver per shard count, the
    // same inputs and θ, timing the zero-alloc steady-state path.
    // "auto" is the PALLAS_SHARDS knob value the configs default to.
    let shard_auto = pool::default_shards();
    let mut shard_rows = Vec::new();
    let mut shard_gops_s1 = 0.0f64;
    let mut shard_gops_auto = 0.0f64;
    for shards in [1usize, 2, shard_auto] {
        let mut scfg = cfg.clone();
        scfg.shards = shards;
        let mut sms = ModelStep::new(scfg, weights.clone());
        sms.controller_mut().thresholds.copy_from_slice(&thetas);
        sms.microstep_in_place(&acts, &grads); // cold build
        sms.microstep_in_place(&acts, &grads); // settle workspaces
        let mut times = Vec::with_capacity(disp_iters);
        for _ in 0..disp_iters {
            let t = Instant::now();
            sms.microstep_in_place(&acts, &grads);
            times.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let steady = median(&times);
        let g = flops / (steady / 1e3) / 1e9;
        if shards == 1 {
            shard_gops_s1 = g;
        }
        if shards == shard_auto {
            shard_gops_auto = g;
        }
        shard_rows.push(obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("auto", Json::Bool(shards == shard_auto)),
            ("steady_ms", Json::Num(steady)),
            ("gops", Json::Num(g)),
        ]));
    }
    let shard_scaling = shard_gops_auto / shard_gops_s1.max(1e-12);
    println!(
        "shard scaling (warm microstep): S=1 {shard_gops_s1:.2} Gops \
         vs S=auto({shard_auto}) {shard_gops_auto:.2} Gops = \
         {shard_scaling:.2}x"
    );

    // -- summaries ----------------------------------------------------
    let cold_steady = median(&cold_ms);
    let cached_steady = median(&cached_ms[1..]);
    let warm_steady = median(&warm_restored_ms);
    let cold_gops = flops / (cold_steady / 1e3) / 1e9;
    let cached_gops = flops / (cached_steady / 1e3) / 1e9;
    let warm_gops = flops / (warm_steady / 1e3) / 1e9;
    let speedup = cold_steady / cached_steady;

    // per-layer warm hit rates + executed rates (last microstep)
    let layer_label = |l: usize| -> String {
        if l < layers {
            format!("layer{l}")
        } else {
            "lm_head".into()
        }
    };
    let spl = sites_per_layer(cfg.glu);
    let group_sites = |l: usize| {
        if l < layers {
            spl * l..spl * (l + 1)
        } else {
            spl * layers..n_sites
        }
    };
    let mut per_layer = Vec::new();
    for l in 0..=layers {
        let r = group_sites(l);
        let (h, m): (u64, u64) = r.clone().fold((0, 0), |(h, m), s| {
            (h + site_hits[s], m + site_misses[s])
        });
        let hit_rate = h as f64 / (h + m).max(1) as f64;
        let fwd: f64 = r.clone()
            .map(|s| last_rep.sites[s].fallback_rate)
            .sum::<f64>() / r.clone().count() as f64;
        let bwd: f64 = r.clone()
            .map(|s| last_rep.sites[s].bwd_fallback_rate)
            .sum::<f64>() / r.count() as f64;
        per_layer.push((layer_label(l), hit_rate, fwd, bwd));
    }
    assert!(per_layer.iter().all(|&(_, hr, _, _)| hr == 1.0),
            "every layer (and the head) must hit from microstep 2");

    // resident bytes the warm cache keeps alive
    let resident_bytes: usize = ms
        .cache()
        .keys()
        .iter()
        .filter_map(|k| ms.cache().peek(k))
        .map(|wp| wp.packed_bytes())
        .sum();

    let mean_rate = last_rep
        .sites
        .iter()
        .map(|s| s.fallback_rate)
        .sum::<f64>() / n_sites as f64;
    let sub_ms = cal.substrate_model_step_secs(
        layers, d_model, d_ff, cfg.glu, vocab, tokens, mean_rate)
        * 1e3;
    let g4090 = rtx4090();
    let proj_ms = cal.projected_model_step_secs(
        &g4090, layers, d_model, d_ff, cfg.glu, vocab, tokens,
        mean_rate) * 1e3;

    let mut table = Table::new(&["run", "first ms", "steady ms",
                                 "Gops", "hit rate"]);
    table.row(&[
        "cold".into(),
        format!("{:.1}", cold_ms[0]),
        format!("{cold_steady:.1}"),
        format!("{cold_gops:.2}"),
        "-".into(),
    ]);
    table.row(&[
        "cached".into(),
        format!("{:.1}", cached_ms[0]),
        format!("{cached_steady:.1}"),
        format!("{cached_gops:.2}"),
        format!("{warm_hit_rate:.2} (2nd+)"),
    ]);
    table.row(&[
        "warm_restored".into(),
        format!("{:.1}", warm_restored_ms[0]),
        format!("{warm_steady:.1}"),
        format!("{warm_gops:.2}"),
        format!("{first_hit_rate:.2} (1st)"),
    ]);
    table.print();
    println!(
        "\ncached vs cold steady-state: {speedup:.2}x; \
         warm-restored first microstep hit rate {first_hit_rate:.2} \
         (target 1.00); composed-LayerStep bit-identity on {} \
         backend(s)", backend_checks.len()
    );
    println!(
        "quant calls / panel packs: cold {}/{}, cached {}/{}",
        qc1 - qc0, pc1 - pc0, qw1 - qw0, pw1 - pw0
    );
    println!(
        "warm cache: {} entries, {:.1} MiB resident, warm-state file \
         {} bytes",
        ms.cache().len(),
        resident_bytes as f64 / (1024.0 * 1024.0),
        state_text.len()
    );
    println!(
        "cost model: substrate estimate {sub_ms:.1} ms/microstep \
         (measured {cached_steady:.1} ms), 4090 projection \
         {proj_ms:.3} ms"
    );
    println!(
        "dispatch: pooled {pooled_steady:.1} ms vs scoped \
         {scoped_steady:.1} ms = {dispatch_ratio:.2}x (target: \
         pooled < scoped); steady-state spawns/microstep \
         {steady_spawns:.1}, workspace growths/microstep \
         {steady_ws:.1} (target 0)"
    );

    let report = obj(vec![
        ("bench", Json::Str("model_step".into())),
        ("smoke", Json::Bool(smoke)),
        ("config", obj(vec![
            ("layers", Json::Num(layers as f64)),
            ("d_model", Json::Num(d_model as f64)),
            ("d_ff", Json::Num(d_ff as f64)),
            ("glu", Json::Bool(cfg.glu)),
            ("vocab", Json::Num(vocab as f64)),
            ("tokens", Json::Num(tokens as f64)),
            ("block", Json::Num(block as f64)),
            ("threads", Json::Num(threads as f64)),
            ("microsteps", Json::Num(microsteps as f64)),
            ("n_sites", Json::Num(n_sites as f64)),
            ("data_path", Json::Str(cfg.path.tag().into())),
            ("kernel_backend",
             Json::Str(ms.kernel_backend().into())),
        ])),
        ("cpu_features",
         Json::Arr(kernels::cpu_features()
             .iter()
             .map(|&f| Json::Str(f.into()))
             .collect())),
        ("flops_per_microstep", Json::Num(flops)),
        ("cache", obj(vec![
            ("capacity", Json::Num(ms.cache().capacity() as f64)),
            ("working_set", Json::Num(cfg.working_set() as f64)),
            ("entries", Json::Num(ms.cache().len() as f64)),
            ("resident_bytes", Json::Num(resident_bytes as f64)),
            ("warm_hit_rate", Json::Num(warm_hit_rate)),
            ("per_microstep", Json::Arr(
                per_microstep
                    .iter()
                    .map(|&(h, m)| obj(vec![
                        ("hits", Json::Num(h as f64)),
                        ("misses", Json::Num(m as f64)),
                    ]))
                    .collect(),
            )),
        ])),
        ("per_layer", Json::Arr(
            per_layer
                .iter()
                .map(|(name, hr, fwd, bwd)| obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("warm_hit_rate", Json::Num(*hr)),
                    ("fwd_fallback_rate", Json::Num(*fwd)),
                    ("bwd_fallback_rate", Json::Num(*bwd)),
                ]))
                .collect(),
        )),
        ("cold", obj(vec![
            ("per_microstep_ms", Json::Arr(
                cold_ms.iter().map(|&x| Json::Num(x)).collect())),
            ("steady_ms", Json::Num(cold_steady)),
            ("gops", Json::Num(cold_gops)),
            ("quant_calls", Json::Num((qc1 - qc0) as f64)),
            ("panel_packs", Json::Num((pc1 - pc0) as f64)),
        ])),
        ("cached", obj(vec![
            ("per_microstep_ms", Json::Arr(
                cached_ms.iter().map(|&x| Json::Num(x)).collect())),
            ("first_ms", Json::Num(cached_ms[0])),
            ("steady_ms", Json::Num(cached_steady)),
            ("gops", Json::Num(cached_gops)),
            ("quant_calls", Json::Num((qw1 - qw0) as f64)),
            ("panel_packs", Json::Num((pw1 - pw0) as f64)),
        ])),
        ("warm_restored", obj(vec![
            ("per_microstep_ms", Json::Arr(
                warm_restored_ms
                    .iter()
                    .map(|&x| Json::Num(x))
                    .collect())),
            ("first_ms", Json::Num(warm_restored_ms[0])),
            ("steady_ms", Json::Num(warm_steady)),
            ("gops", Json::Num(warm_gops)),
            ("first_hit_rate", Json::Num(first_hit_rate)),
            ("state_bytes", Json::Num(state_text.len() as f64)),
            ("calibration_roundtrip", Json::Bool(cal_roundtrip)),
        ])),
        ("backends", Json::Arr(
            backend_checks
                .iter()
                .map(|&(name, ok)| obj(vec![
                    ("name", Json::Str(name.into())),
                    ("bit_identical_vs_layersteps", Json::Bool(ok)),
                ]))
                .collect(),
        )),
        ("dispatch_overhead", obj(vec![
            ("pooled_steady_ms", Json::Num(pooled_steady)),
            ("scoped_steady_ms", Json::Num(scoped_steady)),
            ("scoped_over_pooled", Json::Num(dispatch_ratio)),
            ("steady_spawns_per_microstep",
             Json::Num(steady_spawns)),
            ("steady_ws_allocs_per_microstep",
             Json::Num(steady_ws)),
        ])),
        ("shard_scaling", obj(vec![
            ("auto_shards", Json::Num(shard_auto as f64)),
            ("per_shards", Json::Arr(shard_rows)),
        ])),
        ("criteria", obj(vec![
            ("cached_vs_cold", Json::Num(speedup)),
            ("shard_scaling", Json::Num(shard_scaling)),
            ("warm_hit_rate", Json::Num(warm_hit_rate)),
            ("dispatch_scoped_over_pooled",
             Json::Num(dispatch_ratio)),
            ("warm_restored_first_hit_rate",
             Json::Num(first_hit_rate)),
            ("warm_restored_bit_identical",
             Json::Bool(warm_restored_identical)),
            ("bit_identical_all_backends",
             Json::Bool(backend_checks.iter().all(|&(_, ok)| ok))),
        ])),
        ("projection", obj(vec![
            ("substrate_ms", Json::Num(sub_ms)),
            ("rtx4090_ms", Json::Num(proj_ms)),
            ("calibration_int8_gops", Json::Num(cal.int8_gops)),
            ("calibration_backend", Json::Str(cal.backend.into())),
        ])),
    ]);
    std::fs::write("BENCH_model_step.json", report.to_string())
        .expect("write BENCH_model_step.json");
    println!("\nwrote BENCH_model_step.json");
}
