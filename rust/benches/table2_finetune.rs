//! Table 2: finetune quality + CAL-FLOPS + ACT-MEM per method.
//!
//! Quality columns are *measured* on the synthetic task suite (tiny
//! profile — the paper's 1.5B-8B models don't fit this testbed);
//! CAL-FLOPS and ACT-MEM columns are *modeled* for the paper's actual
//! model dims on the RTX 4090 roofline + the memory accounting of §5,
//! so the speedup/memory ratios are directly comparable to Table 2.

#[path = "common.rs"]
mod common;

use dbfq::coordinator::TrainConfig;
use dbfq::costmodel::rtx4090;
use dbfq::data::{answer_span_loss, Task};
use dbfq::model::{act_mem_bytes, Method};
use dbfq::runtime::ProfileMeta;
use dbfq::util::bench::Table;
use dbfq::util::rng::Pcg64;

/// Paper model dims (d_model, n_layers, d_ff, seq, microbatch).
fn paper_models() -> Vec<(&'static str, ProfileMeta)> {
    let mk = |name: &'static str, d, l, ff, batch| {
        (name, ProfileMeta {
            name: name.to_string(),
            vocab: 152_064, // Qwen tokenizer order
            d_model: d,
            n_layers: l,
            n_heads: d / 128,
            d_ff: ff,
            seq_len: 1024,
            glu: true,
            batch,
            block: 128,
            group: 128,
            n_params: 0,
            n_sites: 4 * l + 1,
            param_layout: vec![],
        })
    };
    vec![
        mk("Qwen2.5-1.5B", 1536, 28, 8960, 2),
        mk("Qwen2.5-3B", 2048, 36, 11008, 2),
        mk("Llama-3.2-1B", 2048, 16, 8192, 2),
        mk("Llama-3.1-8B", 4096, 32, 14336, 1),
    ]
}

/// Modeled per-microstep GEMM throughput (CAL-FLOPS analogue): total
/// GEMM flops / modeled step time on a 4090.
fn cal_flops(p: &ProfileMeta, m: Method) -> f64 {
    let g = rtx4090();
    let tokens = p.batch * p.seq_len;
    let (int8, kg, rate) = match m {
        Method::Bf16 => (false, 128, 0.0),
        Method::Block => (true, 128, 0.0),
        Method::Jetfire => (true, 32, 0.0),
        Method::Fallback => (true, 128, 0.2),
    };
    let mut secs = 0.0;
    for l in dbfq::model::layer_linears(p.d_model, p.d_ff, p.glu, tokens) {
        let fwd = if int8 {
            g.int8_gemm_secs(l.m, l.n, l.k, kg, rate)
        } else {
            g.bf16_gemm_secs(l.m, l.n, l.k)
        };
        let bwd = if int8 {
            g.int8_gemm_secs(l.m, l.k, l.n, kg, 0.0)
                + g.int8_gemm_secs(l.n, l.k, l.m, kg, 0.0)
        } else {
            g.bf16_gemm_secs(l.m, l.k, l.n) + g.bf16_gemm_secs(l.n, l.k, l.m)
        };
        secs += (fwd + bwd) * p.n_layers as f64;
    }
    // attention bf16 in all methods (fwd + 2x bwd)
    secs += 3.0 * 2.0 * g.bf16_gemm_secs(tokens, tokens, p.d_model)
        * p.n_layers as f64;
    dbfq::model::train_step_gemm_flops(p) / secs / 1e12
}

fn main() {
    common::banner("Table 2 — finetune quality + CAL-FLOPS + ACT-MEM",
                   "Table 2, §6.1");
    let rt = common::runtime();
    let steps = common::bench_steps(50);
    let prof = rt.profile("tiny").unwrap().clone();

    // measured quality: answer-span loss per method per task
    let mut tq = Table::new(&["method", "arith", "span", "choice",
                              "cont"]);
    for method in Method::all() {
        let mut cells = vec![method.tag().to_string()];
        for task in Task::all() {
            let mut cfg = TrainConfig::new("tiny", method, 3, steps);
            cfg.lr.peak = 1e-3;
            let mut tr =
                dbfq::coordinator::Trainer::new(&rt, cfg).unwrap();
            let mut rng = Pcg64::new(17);
            for _ in 0..steps {
                let (toks, _) = task.batch(prof.batch, prof.seq_len,
                                           prof.vocab, &mut rng);
                tr.step_on(&toks).unwrap();
            }
            let mut erng = Pcg64::new(0xE7A1);
            let mut sl = 0.0;
            for _ in 0..6 {
                let (toks, spans) = task.batch(
                    prof.batch, prof.seq_len, prof.vocab, &mut erng);
                let per = tr.eval_per_token(&toks).unwrap();
                sl += answer_span_loss(&per, prof.batch, prof.seq_len,
                                       &spans);
            }
            cells.push(format!("{:.3}", sl / 6.0));
        }
        tq.row(&cells);
    }
    println!("measured answer-span loss on tiny (lower = better; the \
              paper reports Acc/F1 on 1.5B-8B models):");
    tq.print();

    // modeled CAL-FLOPS + ACT-MEM for the paper's models
    let mut tm = Table::new(&["model", "method", "CAL-FLOPS(T)",
                              "speedup", "ACT-MEM(GB)", "mem %bf16"]);
    for (name, p) in paper_models() {
        let base_flops = cal_flops(&p, Method::Bf16);
        let base_mem = act_mem_bytes(&p, Method::Bf16);
        for m in Method::all() {
            let f = cal_flops(&p, m);
            let mem = act_mem_bytes(&p, m);
            tm.row(&[
                name.into(),
                m.tag().into(),
                format!("{f:.0}"),
                format!("{:.2}x", f / base_flops),
                format!("{:.2}", mem / 1e9),
                format!("{:.0}%", 100.0 * mem / base_mem),
            ]);
        }
    }
    println!("\nmodeled on RTX4090 (paper Table 2: Ours 1.38-1.57x \
              CAL-FLOPS, ACT-MEM ~61-62% of BF16):");
    tm.print();
}
