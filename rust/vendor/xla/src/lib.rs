//! Offline stub of the `xla` crate's PJRT bindings.
//!
//! The real crate links against the XLA C++ runtime, which is not
//! present in this build environment. This stub keeps the exact API
//! surface `dbfq::runtime` compiles against:
//!
//! * **Host-side `Literal`s work for real** (construction, reshape,
//!   tuple unpacking, readback) — the `runtime::value` marshalling tests
//!   exercise them without any device.
//! * **Device entry points degrade gracefully**: `PjRtClient::cpu()`
//!   succeeds (so manifests can be inspected), but compiling or
//!   executing an HLO module returns an error explaining that PJRT is
//!   unavailable, which `Runtime` surfaces through its `Result` API.
//!
//! When a real `xla` crate is available, point the `xla` dependency in
//! `rust/Cargo.toml` at it; no `dbfq` source changes are needed.

use std::borrow::Borrow;
use std::fmt;

const UNAVAILABLE: &str =
    "XLA/PJRT is unavailable in this offline build (stub `xla` crate); \
     artifact execution requires the real xla bindings";

/// Error type with the `{:?}`-printability the callers rely on.
#[derive(Clone)]
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold on the host.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host tensor literal (row-major), mirroring `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Tuple literal (used by artifact outputs).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { data: Data::Tuple(parts), dims: vec![n] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(XlaError("cannot reshape a tuple literal".into()));
        }
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.len() {
            return Err(XlaError(format!(
                "reshape to {:?}: {} elements required, literal has {}",
                dims,
                count,
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements back to a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| XlaError("literal dtype mismatch".into()))
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module handle. The stub never parses anything.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(XlaError(format!("{UNAVAILABLE} (while loading {path})")))
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError(UNAVAILABLE.into()))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(UNAVAILABLE.into()))
    }
}

/// PJRT client. Construction succeeds so manifest-only workflows run.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub(no-xla)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(XlaError(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[7i32]);
        let s = l.reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_unpack() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[2i32]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
