//! Offline drop-in subset of the `anyhow` crate.
//!
//! The real `anyhow` is not available in this offline build environment,
//! so this shim vendors the small API surface the workspace actually
//! uses: `Error`, `Result<T>`, `anyhow!`, `bail!`, and `Error::msg`.
//! Errors carry a message string only (no backtraces, no source chains);
//! that is all the callers rely on.

use std::fmt;

/// A message-carrying error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow prints the message (not a struct dump) for {:?} too.
        f.write_str(&self.msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

/// `Result` defaulting the error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        if flag {
            bail!("flagged with {}", 42);
        }
        Err(anyhow!("plain"))
    }

    #[test]
    fn macros_and_display() {
        assert_eq!(fails(true).unwrap_err().to_string(), "flagged with 42");
        assert_eq!(fails(false).unwrap_err().to_string(), "plain");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e:?}"), "owned");
    }

    #[test]
    fn io_error_propagates() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
