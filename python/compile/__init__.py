"""DBFQ compile-time Python package (L1 Pallas kernels + L2 JAX model).

Runs only at ``make artifacts`` time; never imported on the Rust request
path.
"""
