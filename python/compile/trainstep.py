"""L2 train/eval/probe steps with a flat, Rust-friendly interface.

The AOT artifacts exchange only plain tensors with the Rust runtime:

  train_step(params_flat, m_flat, v_flat, step, tokens, seed, theta_flat,
             qscalars) -> (params', m', v', loss, rates, grad_norm)

  eval_step(params_flat, tokens, theta_flat, qscalars[, prefix_len])
             -> (mean_loss, per_token_loss, rates)

  probe_grads(params_flat, tokens, seed, theta_flat, qscalars)
             -> (loss, grads_flat, rates)

``qscalars`` is a (11,) f32 vector (see ``QSCALAR_NAMES``); ``theta_flat``
is (4*L+1,). The learning-rate schedule runs in Rust and arrives via a
(3,) ``opt`` vector [lr, weight_decay, grad_clip]. All of these are traced
inputs — the Rust coordinator sweeps them without recompiling.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import model as M
from . import quantized as Q

QSCALAR_NAMES = ["levels_x", "levels_w", "levels_dy", "sr_dy", "sr_ctx",
                 "fallback_bwd", "crit0", "crit1", "crit2", "ctx_bits",
                 "nl_in_bits"]

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def unpack_qparams(mcfg: M.ModelConfig, theta_flat, qscalars):
    n_l = mcfg.n_layers
    return {
        "theta": theta_flat[: 4 * n_l].reshape(n_l, 4),
        "theta_head": theta_flat[4 * n_l],
        "levels_x": qscalars[0],
        "levels_w": qscalars[1],
        "levels_dy": qscalars[2],
        "sr_dy": qscalars[3],
        "sr_ctx": qscalars[4],
        "fallback_bwd": qscalars[5],
        "crit": qscalars[6:9],
        "ctx_bits": qscalars[9],
        "nl_in_bits": qscalars[10],
    }


def default_qscalars() -> jnp.ndarray:
    """Paper-default runtime quantization scalars (INT8, SR on, AbsMax)."""
    return jnp.array([127.0, 127.0, 127.0, 1.0, 1.0, 0.0,
                      1.0, 0.0, 0.0, 10.0, 15.0], jnp.float32)


def _split_batch(tokens):
    """(B, T+1) token block -> (inputs, targets)."""
    return tokens[:, :-1], tokens[:, 1:]


def make_train_step(qcfg: Q.QuantConfig, mcfg: M.ModelConfig):
    """Build the AdamW train step over flat buffers."""

    def train_step(params_flat, m_flat, v_flat, step, tokens, seed,
                   theta_flat, qscalars, opt):
        params = M.unflatten_params(mcfg, params_flat)
        qp = unpack_qparams(mcfg, theta_flat, qscalars)
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        inputs, targets = _split_batch(tokens)

        def lf(p):
            return M.loss_fn(qcfg, mcfg, p, inputs, targets, qp, key)

        (loss, (rates, _)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        g = M.flatten_params(grads)

        # Global-norm clip (opt[2]; 0 disables), then AdamW.
        gn = jnp.sqrt(jnp.sum(g * g))
        clip = opt[2]
        scale = jnp.where(clip > 0, jnp.minimum(1.0, clip / (gn + 1e-12)), 1.0)
        g = g * scale

        step1 = step + 1.0
        m_new = ADAM_B1 * m_flat + (1 - ADAM_B1) * g
        v_new = ADAM_B2 * v_flat + (1 - ADAM_B2) * g * g
        mhat = m_new / (1 - ADAM_B1 ** step1)
        vhat = v_new / (1 - ADAM_B2 ** step1)
        upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        lr, wd = opt[0], opt[1]
        params_new = params_flat - lr * (upd + wd * params_flat)
        return params_new, m_new, v_new, loss, rates, gn

    return train_step


def make_eval_step(qcfg: Q.QuantConfig, mcfg: M.ModelConfig,
                   with_prefix: bool = False):
    """Per-token eval loss. With ``with_prefix``, activations of tokens
    >= prefix_len are zero-masked before every quantization step — the
    "Quant (no leakage)" evaluation of Table 4."""

    if with_prefix:
        def eval_step(params_flat, tokens, theta_flat, qscalars, prefix_len):
            params = M.unflatten_params(mcfg, params_flat)
            qp = unpack_qparams(mcfg, theta_flat, qscalars)
            key = jax.random.PRNGKey(0)
            inputs, targets = _split_batch(tokens)
            loss, (rates, per_tok) = M.loss_fn(
                qcfg, mcfg, params, inputs, targets, qp, key,
                quant_prefix_len=prefix_len)
            return loss, per_tok, rates
    else:
        def eval_step(params_flat, tokens, theta_flat, qscalars):
            params = M.unflatten_params(mcfg, params_flat)
            qp = unpack_qparams(mcfg, theta_flat, qscalars)
            key = jax.random.PRNGKey(0)
            inputs, targets = _split_batch(tokens)
            loss, (rates, per_tok) = M.loss_fn(
                qcfg, mcfg, params, inputs, targets, qp, key)
            return loss, per_tok, rates

    return eval_step


def make_probe_grads(qcfg: Q.QuantConfig, mcfg: M.ModelConfig):
    """loss + flat grads + rates — the ablation workhorse (Figs 3c/5/7a):
    the Rust side sweeps qscalars/theta and cosine-compares grads against
    a high-precision reference run of the same artifact."""

    def probe(params_flat, tokens, seed, theta_flat, qscalars):
        params = M.unflatten_params(mcfg, params_flat)
        qp = unpack_qparams(mcfg, theta_flat, qscalars)
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        inputs, targets = _split_batch(tokens)

        def lf(p):
            return M.loss_fn(qcfg, mcfg, p, inputs, targets, qp, key)

        (loss, (rates, _)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, M.flatten_params(grads), rates

    return probe


def make_init(mcfg: M.ModelConfig):
    """Flat parameter initializer (runs once on the Rust side)."""

    def init(seed):
        key = jax.random.fold_in(jax.random.PRNGKey(42), seed)
        return M.flatten_params(M.init_params(mcfg, key))

    return init


def make_activation_probe(qcfg: Q.QuantConfig, mcfg: M.ModelConfig,
                          layer_index: int):
    """Capture the DownProj input (GLU output) of one layer — the tensor
    the paper's outlier analysis (§4.1, Fig 2c, Fig 4a) examines."""

    def probe(params_flat, tokens, theta_flat, qscalars):
        params = M.unflatten_params(mcfg, params_flat)
        qp = unpack_qparams(mcfg, theta_flat, qscalars)
        inputs, _ = _split_batch(tokens)
        x = params["emb"][inputs]
        captured = None
        blocks = params["blocks"]
        key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, mcfg.n_layers)
        # Unrolled (not scanned) so one layer's activation can be captured;
        # only used with small probe models.
        for li in range(mcfg.n_layers):
            blk = jax.tree.map(lambda a: a[li], blocks)
            b, t, d = x.shape
            h = Q.rmsnorm_ctx(qcfg, x, blk["ln1"], qp)
            qkv, _ = Q.quantized_linear(qcfg, h, blk["wqkv"], qp,
                                        qp["theta"][li, 0], keys[li])
            qkv = qkv.reshape(b, t, 3, mcfg.n_heads, mcfg.head_dim)
            a = M._attention(M._rope(qkv[:, :, 0]), M._rope(qkv[:, :, 1]),
                             qkv[:, :, 2], mcfg.head_dim).reshape(b, t, d)
            ao, _ = Q.quantized_linear(qcfg, a, blk["wo"], qp,
                                       qp["theta"][li, 1], keys[li])
            x = x + ao
            h = Q.rmsnorm_ctx(qcfg, x, blk["ln2"], qp)
            hin, _ = Q.quantized_linear(qcfg, h, blk["win"], qp,
                                        qp["theta"][li, 2], keys[li])
            if mcfg.glu:
                g, u = jnp.split(hin, 2, axis=-1)
                act = Q.swiglu_ctx(qcfg, g, u, qp)
            else:
                act = Q.gelu_ctx(qcfg, hin, qp)
            if li == layer_index:
                captured = act
            mo, _ = Q.quantized_linear(qcfg, act, blk["wdown"], qp,
                                       qp["theta"][li, 3], keys[li])
            x = x + mo
        return captured

    return probe
