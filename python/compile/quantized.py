"""L2 quantized training ops (paper §5: Training System Design).

Implements the paper's linear-layer recipe as a ``jax.custom_vjp``:

  forward   Y = X W^T with *fallback* quantization of X (Algorithm 1)
            and plain block quantization of W; the activation context is
            X re-quantized with *stochastic rounding* (so the stored
            context is pure INT8, §5.1).
  backward  ∇Y is stochastically block-quantized once and used in two
            plain block GEMMs: ∇X = ∇Y_q W_q and ∇W = ∇Y_q^T X_q.

plus the non-linear context compression (§5.2): RMSNorm / SwiGLU keep
BF16 data flow but store their backward context in n-bit 1×G groups.

All quantization *parameters* (levels = 2^(bits-1)-1, thresholds θ,
stochastic-rounding switches, fallback-criterion one-hot, context bits,
fallback-in-backward switch) are **traced scalars**: the Rust coordinator
feeds them at run time, so one AOT artifact serves every ablation sweep
(Figs 3c, 5a, 5b, 6a, 7a) and the delay-threshold controller (Alg 2)
adjusts θ between steps without recompilation.

Graph-*structural* choices (precision mode, block size, group size) are
baked per artifact via :class:`QuantConfig`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import ref

# Mode constants (graph-structural).
BF16 = "bf16"          # high-precision baseline (f32 on the CPU backend)
BLOCK = "block"        # per-block INT8 GEMM only (paper's "Block" baseline)
FALLBACK = "fallback"  # ours: dynamic block-level fallback
JETFIRE = "jetfire"    # 32x32 blocks + INT8 non-linear dataflow


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static (trace-time) quantization configuration."""
    mode: str = FALLBACK
    block: int = 128          # quantization block size B (paper: 128)
    group: int = 128          # 1 x group size for non-linear contexts
    nonlinear_int8: bool = False  # Jetfire-style INT8 non-linear dataflow

    @property
    def quantized(self) -> bool:
        return self.mode != BF16


def default_qparams(n_layers: int, theta0: float = 1.0) -> Dict[str, Any]:
    """Runtime quantization parameters with paper-default values.

    theta:   (n_layers, 4) per-linear-site fallback thresholds
             (sites per block: 0 attn-in, 1 attn-out, 2 mlp-in, 3 mlp-down)
    theta_head: scalar threshold for the LM head input
    levels_x/w/dy: quantization levels (2^(bits-1)-1; 127 = INT8)
    sr_dy:   1.0 -> stochastic rounding of ∇Y (paper default), 0.0 -> RTN
    sr_ctx:  1.0 -> stochastic rounding of the stored X context
    fallback_bwd: 1.0 -> ∇W consumes the 16-bit fallback X (Fig 5b
             ablation); 0.0 -> plain INT8 stochastic context (paper default)
    crit:    (3,) one-hot criterion selector [AbsMax, L1, L1-Rel] (§4.4)
    ctx_bits: bit-width for non-linear 1xG contexts (paper: 10)
    """
    return {
        "theta": jnp.full((n_layers, 4), theta0, jnp.float32),
        "theta_head": jnp.float32(theta0),
        "levels_x": jnp.float32(127.0),
        "levels_w": jnp.float32(127.0),
        "levels_dy": jnp.float32(127.0),
        "sr_dy": jnp.float32(1.0),
        "sr_ctx": jnp.float32(1.0),
        "fallback_bwd": jnp.float32(0.0),
        "crit": jnp.array([1.0, 0.0, 0.0], jnp.float32),
        "ctx_bits": jnp.float32(10.0),
        # forward-path non-linear *input* quantization (Fig 6a sweep);
        # >= 15 bits means "off" (BF16 data flow, the paper's choice)
        "nl_in_bits": jnp.float32(15.0),
    }


# ---------------------------------------------------------------------------
# quantization helpers on top of kernels/ref.py
# ---------------------------------------------------------------------------

def _quant_rtn(x, block, levels):
    q, s, _ = ref.block_quant_ref(x, block, levels)
    return q, s


def _quant_sr(x, key, block, levels, sr):
    """Stochastic (sr=1) or nearest (sr=0) rounding; sr is a traced scalar.

    floor(x/a + u) with u ~ U[0,1) is stochastic rounding; u = 0.5 is
    round-half-up (≈ RTN; differs from round-ties-even only at exact .5).
    """
    noise = jax.random.uniform(key, x.shape, jnp.float32)
    eff = sr * noise + (1.0 - sr) * 0.5
    q, s, _ = ref.block_quant_stochastic_ref(x, eff, block, levels)
    return q, s


def _criterion_mask(x, theta, crit, block, levels):
    """u = [metric > theta] with metric selected by the one-hot ``crit``."""
    m = ref.criterion_metrics_ref(x, block, levels)
    metric = (crit[0] * m["absmax"] + crit[1] * m["l1"]
              + crit[2] * m["l1rel"])
    return (metric > theta).astype(jnp.float32)


def _fallback_quant(x, theta, crit, block, levels):
    """Fallback quantization with a selectable criterion (§4.4)."""
    fq = ref.fallback_quant_ref(x, jnp.inf, block, levels)
    fq["u"] = _criterion_mask(x, theta, crit, block, levels)
    return fq


# ---------------------------------------------------------------------------
# quantized linear layer: Y = X @ W^T  (+ fallback rate as aux output)
# ---------------------------------------------------------------------------

def _linear_fwd_quant(cfg: QuantConfig, x2d, w, qp, theta, key):
    """Shared forward math. Returns (y2d, rate, context).

    GEMMs are evaluated in *scale-factored* form: C = deq(A) @ deq(B),
    which is algebraically identical to Eq. 1 (per-block scales factor
    out of the int block product) and — because int8 code products with
    block <= 1024 stay below 2^24 — numerically equal to the exact
    int32 kernel path up to one f32 rounding per element. This keeps the
    lowered HLO on XLA:CPU's fast dense f32 matmul instead of a naive
    int32 dot (≈10x faster train steps; see EXPERIMENTS.md §Perf).
    pytest cross-checks this form against the exact `block_gemm_ref`.
    """
    kx, kctx = jax.random.split(key)
    b, lx, lw, ldy = cfg.block, qp["levels_x"], qp["levels_w"], qp["levels_dy"]
    wt = w.T  # (K, N)
    qw, sw = _quant_rtn(wt, b, lw)
    w_deq = ref.block_dequant_ref(qw, sw, wt.shape)

    if cfg.mode == FALLBACK:
        fx = _fallback_quant(x2d, theta, qp["crit"], b, lx)
        x_deq = ref.fallback_dequant_ref(fx, x2d.shape)
        y = x_deq @ w_deq
        rate = jnp.mean(fx["u"])
    else:
        qx, sx = _quant_rtn(x2d, b, lx)
        x_deq = ref.block_dequant_ref(qx, sx, x2d.shape)
        y = x_deq @ w_deq
        rate = jnp.float32(0.0)

    # Activation context: stochastically re-quantized X (pure INT8), plus
    # optionally the fallback residual (Fig 5b "both passes" ablation).
    qxc, sxc = _quant_sr(x2d, kctx, b, lx, qp["sr_ctx"])
    if cfg.mode == FALLBACK:
        # Blend: fallback_bwd=1 stores the 16-bit fallback X instead.
        fb = qp["fallback_bwd"]
        x_ctx = (1.0 - fb) * ref.block_dequant_ref(qxc, sxc, x2d.shape)
        x_ctx = x_ctx + fb * ref.fallback_dequant_ref(fx, x2d.shape)
    else:
        x_ctx = ref.block_dequant_ref(qxc, sxc, x2d.shape)
    return y, rate, x_ctx


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def quantized_linear(cfg: QuantConfig, x, w, qp, theta, key):
    """Quantized Y = X @ W^T per paper §5.1.

    x: (..., K); w: (N, K); theta: scalar threshold for this site;
    key: PRNG key for stochastic rounding. Returns (y, fallback_rate).
    """
    if cfg.mode == BF16:
        return x @ w.T, jnp.float32(0.0)
    x2d = x.reshape(-1, x.shape[-1])
    y, rate, _ = _linear_fwd_quant(cfg, x2d, w, qp, theta, key)
    return y.reshape(*x.shape[:-1], w.shape[0]), rate


def _ql_fwd(cfg, x, w, qp, theta, key):
    if cfg.mode == BF16:
        y = x @ w.T
        return (y, jnp.float32(0.0)), (x, w, qp, None)
    x2d = x.reshape(-1, x.shape[-1])
    y, rate, x_ctx = _linear_fwd_quant(cfg, x2d, w, qp, theta, key)
    res = (x_ctx, w, qp, key, x.shape)
    return (y.reshape(*x.shape[:-1], w.shape[0]), rate), res


def _ql_bwd(cfg, res, cts):
    dy, _ = cts  # cotangent of (y, rate); rate is non-differentiable
    if cfg.mode == BF16:
        x, w, qp, _ = res
        dx = dy @ w
        x2d = x.reshape(-1, x.shape[-1])
        dy2d = dy.reshape(-1, dy.shape[-1])
        dw = dy2d.T @ x2d
        return dx, dw, jax.tree.map(jnp.zeros_like, qp), \
            jnp.zeros(()), None

    x_ctx, w, qp, key, x_shape = res
    b, ldy = cfg.block, qp["levels_dy"]
    kdy = jax.random.fold_in(key, 7)
    dy2d = dy.reshape(-1, dy.shape[-1])

    # ∇Y stochastically quantized once, used by both GEMMs (§5.1);
    # scale-factored GEMM form (see _linear_fwd_quant).
    qdy, sdy = _quant_sr(dy2d, kdy, b, ldy, qp["sr_dy"])
    dy_deq = ref.block_dequant_ref(qdy, sdy, dy2d.shape)

    # ∇X = ∇Y_q @ W_q : quantize W (not W^T) per-block.
    qw, sw = _quant_rtn(w, b, qp["levels_w"])
    w_deq = ref.block_dequant_ref(qw, sw, w.shape)
    dx = (dy_deq @ w_deq).reshape(x_shape)

    # ∇W = ∇Y_q^T @ X_q : context X is already INT8 (dequantized form);
    # re-quantizing it is exact because its values sit on the quant grid.
    qxc, sxc = _quant_rtn(x_ctx, b, qp["levels_x"])
    xc_deq = ref.block_dequant_ref(qxc, sxc, x_ctx.shape)
    dw = dy_deq.T @ xc_deq

    return dx, dw, jax.tree.map(jnp.zeros_like, qp), jnp.zeros(()), None


quantized_linear.defvjp(_ql_fwd, _ql_bwd)


# ---------------------------------------------------------------------------
# non-linear layers with compressed activation context (paper §5.2)
# ---------------------------------------------------------------------------

def _nl_input(x, bits, group):
    """Optionally quantize a non-linear layer's *input* (Fig 6a):
    active when bits < 15, identity otherwise. Runtime-switchable."""
    x2d = x.reshape(-1, x.shape[-1])
    q, s = ref.group_quant_ref(x2d, group, bits)
    xq = ref.group_dequant_ref(q, s, group).reshape(x.shape)
    return jnp.where(bits < 15.0, xq, x)


def _gq_ctx(x2d, bits, group):
    """Group-quantize a context tensor; returns its dequantized form.

    Storing deq(q) keeps the graph simple while being value-equivalent to
    storing (q, scale): the information content is exactly the n-bit code.
    """
    q, s = ref.group_quant_ref(x2d, group, bits)
    return ref.group_dequant_ref(q, s, group)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def rmsnorm_ctx(cfg: QuantConfig, x, gamma, qp):
    """RMSNorm with n-bit 1xG compressed backward context."""
    x = _nl_input(x, qp["nl_in_bits"], cfg.group)
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    return x / rms * gamma


def _rn_fwd(cfg, x, gamma, qp):
    x = _nl_input(x, qp["nl_in_bits"], cfg.group)
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    y = x / rms * gamma
    x2d = x.reshape(-1, x.shape[-1])
    if cfg.nonlinear_int8:
        # Jetfire: INT8 32x32 block dataflow for non-linear layers.
        q, s, _ = ref.block_quant_ref(x2d, 32, 127.0)
        x_ctx = ref.block_dequant_ref(q, s, x2d.shape).reshape(x.shape)
    else:
        x_ctx = _gq_ctx(x2d, qp["ctx_bits"], cfg.group).reshape(x.shape)
    return y, (x_ctx, gamma)


def _rn_bwd(cfg, res, dy):
    x, gamma = res  # x is the *compressed* context
    # Recompute rms from the compressed x (what the kernel would do).
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    xn = x / rms
    dgamma = jnp.sum(dy * xn, axis=tuple(range(dy.ndim - 1)))
    dxn = dy * gamma
    # d/dx of x/rms: (dxn - xn * mean(dxn * xn)) / rms
    dx = (dxn - xn * jnp.mean(dxn * xn, axis=-1, keepdims=True)) / rms
    return dx, dgamma, None


rmsnorm_ctx.defvjp(_rn_fwd, _rn_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def swiglu_ctx(cfg: QuantConfig, g, u, qp):
    """SwiGLU y = silu(g) * u with compressed backward context (§5.2).

    This is the GLU the paper's outlier analysis targets: the product of
    two activations amplifies outliers (P1) yet is sparse (P3).
    """
    g = _nl_input(g, qp["nl_in_bits"], cfg.group)
    u = _nl_input(u, qp["nl_in_bits"], cfg.group)
    return jax.nn.silu(g) * u


def _sg_fwd(cfg, g, u, qp):
    g = _nl_input(g, qp["nl_in_bits"], cfg.group)
    u = _nl_input(u, qp["nl_in_bits"], cfg.group)
    y = jax.nn.silu(g) * u
    d = g.shape[-1]
    g2, u2 = g.reshape(-1, d), u.reshape(-1, d)
    if cfg.nonlinear_int8:
        qg, sg, _ = ref.block_quant_ref(g2, 32, 127.0)
        qu, su, _ = ref.block_quant_ref(u2, 32, 127.0)
        gc = ref.block_dequant_ref(qg, sg, g2.shape).reshape(g.shape)
        uc = ref.block_dequant_ref(qu, su, u2.shape).reshape(u.shape)
    else:
        gc = _gq_ctx(g2, qp["ctx_bits"], cfg.group).reshape(g.shape)
        uc = _gq_ctx(u2, qp["ctx_bits"], cfg.group).reshape(u.shape)
    return y, (gc, uc)


def _sg_bwd(cfg, res, dy):
    g, u = res
    sg = jax.nn.sigmoid(g)
    silu = g * sg
    dsilu = sg * (1.0 + g * (1.0 - sg))
    dg = dy * u * dsilu
    du = dy * silu
    return dg, du, None


swiglu_ctx.defvjp(_sg_fwd, _sg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def gelu_ctx(cfg: QuantConfig, x, qp):
    """GELU with compressed backward context (non-GLU model variant)."""
    x = _nl_input(x, qp["nl_in_bits"], cfg.group)
    return jax.nn.gelu(x, approximate=True)


def _ge_fwd(cfg, x, qp):
    x = _nl_input(x, qp["nl_in_bits"], cfg.group)
    y = jax.nn.gelu(x, approximate=True)
    x2 = x.reshape(-1, x.shape[-1])
    if cfg.nonlinear_int8:
        q, s, _ = ref.block_quant_ref(x2, 32, 127.0)
        xc = ref.block_dequant_ref(q, s, x2.shape).reshape(x.shape)
    else:
        xc = _gq_ctx(x2, qp["ctx_bits"], cfg.group).reshape(x.shape)
    return y, (xc,)


def _ge_bwd(cfg, res, dy):
    (x,) = res
    _, vjp = jax.vjp(lambda t: jax.nn.gelu(t, approximate=True), x)
    return vjp(dy)[0], None


gelu_ctx.defvjp(_ge_fwd, _ge_bwd)
