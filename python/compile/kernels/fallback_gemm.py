"""L1 Pallas kernel: fallback-quantized GEMM (paper Algorithm 1).

The paper's CUDA kernel assigns one threadblock per C tile and walks the
K dimension, conditionally loading the residual ("fallback") A block when
u(i,k) = 1. The TPU-flavoured Pallas mapping (DESIGN.md
§Hardware-Adaptation):

  * grid = (M/B, N/B, K/B) with k innermost — the BlockSpec index maps
    express the paper's HBM→VMEM tile schedule;
  * the INT8 TensorCore MMA becomes an int8 x int8 → int32
    ``lax.dot_general`` (MXU path on real hardware; exact under
    interpret=True);
  * inter-block accumulation is fp32 in the output ref (paper Eq. 1:
    INT32 block product, FP32 accumulator);
  * the conditional residual load becomes a multiply by the 0/1 mask
    u(i,k) — HLO shapes are static, so we always compute and mask;
    numerics are identical, and the *cost* of conditionality is
    exercised for real in the Rust CPU GEMM substrate.

VMEM per grid step at B = 128: qa 64 KiB + rqa 64 KiB + qb 64 KiB +
C accumulator 64 KiB + scalars ≈ 256 KiB (f32 staging; 112 KiB with
native i8 tiles) — far below ~16 MiB, double-buffering friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fallback_gemm_kernel(qa_ref, sa_ref, rqa_ref, rsa_ref, u_ref,
                          qb_ref, sb_ref, o_ref):
    """One (i, j, k) grid step: C_ij += deq(A_ik · B_kj) [+ residual]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qa = qa_ref[...].astype(jnp.int32)
    qb = qb_ref[...].astype(jnp.int32)
    # INT8 x INT8 -> INT32 block product (TensorCore / MXU path).
    prod = jax.lax.dot_general(
        qa, qb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    scale = sa_ref[0, 0] * sb_ref[0, 0]
    acc = prod * scale

    # Fallback block (Algorithm 1 lines 13-16): masked residual product.
    rqa = rqa_ref[...].astype(jnp.int32)
    rprod = jax.lax.dot_general(
        rqa, qb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    rscale = u_ref[0, 0] * rsa_ref[0, 0] * sb_ref[0, 0]
    acc = acc + rprod * rscale

    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block",))
def fallback_gemm(qa, sa, rqa, rsa, u, qb, sb, block: int = 128):
    """Mixed-precision GEMM per Algorithm 1.

    Args (all f32; q tensors hold int8-valued entries):
      qa, rqa : (M, K) first-step and residual quantized A
      sa, rsa : (M/B, K/B) scales
      u       : (M/B, K/B) {0,1} fallback indicators
      qb      : (K, N) quantized B
      sb      : (K/B, N/B) scales
    Returns C : (M, N) f32.
    """
    m, k = qa.shape
    k2, n = qb.shape
    assert k == k2
    assert m % block == 0 and n % block == 0 and k % block == 0
    grid = (m // block, n // block, k // block)

    a_spec = pl.BlockSpec((block, block), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((block, block), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((block, block), lambda i, j, kk: (i, j))
    sa_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk))
    sb_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j))

    return pl.pallas_call(
        _fallback_gemm_kernel,
        grid=grid,
        in_specs=[a_spec, sa_spec, a_spec, sa_spec, sa_spec, b_spec, sb_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(qa, sa, rqa, rsa, u, qb, sb)


def _block_gemm_kernel(qa_ref, sa_ref, qb_ref, sb_ref, o_ref):
    """Plain block-quantized GEMM step (paper Eq. 1, no fallback)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        qa_ref[...].astype(jnp.int32), qb_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    o_ref[...] += prod * (sa_ref[0, 0] * sb_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("block",))
def block_gemm(qa, sa, qb, sb, block: int = 128):
    """Plain block-quantized GEMM (paper Eq. 1) as a Pallas kernel."""
    m, k = qa.shape
    k2, n = qb.shape
    assert k == k2
    assert m % block == 0 and n % block == 0 and k % block == 0
    grid = (m // block, n // block, k // block)

    a_spec = pl.BlockSpec((block, block), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((block, block), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((block, block), lambda i, j, kk: (i, j))
    sa_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk))
    sb_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j))

    return pl.pallas_call(
        _block_gemm_kernel,
        grid=grid,
        in_specs=[a_spec, sa_spec, b_spec, sb_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(qa, sa, qb, sb)
