"""L1 Pallas kernels: 1 x G per-group quantization for non-linear
activation contexts (paper §5.2).

The paper compresses the inputs of Normalization/Activation layers to
INT10 with 1 x 128 groups before storing them as backward context (5/8 of
BF16 memory), dequantizing them in the backward kernel. Per-token groups
make this fusable into the non-linear kernels themselves.

Grid maps one (row-tile, group) pair per step; ``bits`` is a *traced*
scalar so the Rust side can sweep context precision (Fig 6a / 7a) without
recompiling artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8  # rows handled per grid step; groups stay 1 x G logically


def _group_quant_kernel(x_ref, l_ref, q_ref, s_ref):
    """Quantize ROW_TILE rows x one group of G channels."""
    x = x_ref[...]
    levels = l_ref[0, 0]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax * (1.0 / levels), 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -levels, levels)
    s_ref[...] = scale


def _group_dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...] * s_ref[...]


@functools.partial(jax.jit, static_argnames=("group",))
def group_quant(x: jnp.ndarray, bits: jnp.ndarray, group: int = 128):
    """Per-(1 x group) quantization at a runtime-chosen bit width.

    Returns (q, scale): q shaped like x (integer-valued f32), scale
    (M, N/G). Matches :func:`ref.group_quant_ref` exactly.
    """
    m, n = x.shape
    assert m % ROW_TILE == 0 and n % group == 0
    grid = (m // ROW_TILE, n // group)
    levels = (2.0 ** (jnp.asarray(bits, jnp.float32) - 1.0) - 1.0)
    levels = levels.reshape(1, 1)

    x_spec = pl.BlockSpec((ROW_TILE, group), lambda i, j: (i, j))
    l_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    s_spec = pl.BlockSpec((ROW_TILE, 1), lambda i, j: (i, j))
    q, s = pl.pallas_call(
        _group_quant_kernel,
        grid=grid,
        in_specs=[x_spec, l_spec],
        out_specs=[x_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n // group), x.dtype),
        ],
        interpret=True,
    )(x, levels)
    return q, s


@functools.partial(jax.jit, static_argnames=("group",))
def group_dequant(q: jnp.ndarray, scale: jnp.ndarray, group: int = 128):
    """Dequantize a 1 x group representation back to dense."""
    m, n = q.shape
    assert m % ROW_TILE == 0 and n % group == 0
    grid = (m // ROW_TILE, n // group)
    q_spec = pl.BlockSpec((ROW_TILE, group), lambda i, j: (i, j))
    s_spec = pl.BlockSpec((ROW_TILE, 1), lambda i, j: (i, j))
    return pl.pallas_call(
        _group_dequant_kernel,
        grid=grid,
        in_specs=[q_spec, s_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), q.dtype),
        interpret=True,
    )(q, scale)
