"""Pure-jnp reference oracles for the DBFQ numeric format.

These are the ground truth the Pallas kernels (and the Rust `quant`/`gemm`
modules, via exported HLO artifacts) are validated against. Everything here
is written with plain vectorized jnp ops — no Pallas — so it lowers to
fast, fusable HLO; the L2 model reuses these same functions so the
train-step artifacts stay tractable on the CPU PJRT backend while being
bit-identical (asserted by pytest) to the L1 kernels.

Conventions (paper §3.1, §4.3):
  * A quantization *block* is a ``B x B`` tile (default ``B = 128``).
  * Scale ``a = absmax / L`` with ``L = 127`` for INT8; zero blocks get
    scale 1.0 so dequantization is exact.
  * Fallback representation of a block G is ``[Q(G), Q(G - Q(G))]`` — two
    INT8 blocks with independent scales (paper §4.3).
  * Int products inside a block accumulate exactly (int32); across K
    blocks accumulation is fp32 (paper Eq. 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_L = 127.0


# ---------------------------------------------------------------------------
# Block partitioning helpers
# ---------------------------------------------------------------------------

def pad_to_block(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Zero-pad a 2-D matrix so both dims are multiples of ``block``."""
    m, n = x.shape
    pm = (-m) % block
    pn = (-n) % block
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def to_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """(M, N) -> (M/B, N/B, B, B) view of block tiles (pads first)."""
    x = pad_to_block(x, block)
    m, n = x.shape
    x = x.reshape(m // block, block, n // block, block)
    return x.transpose(0, 2, 1, 3)


def from_blocks(xb: jnp.ndarray, shape) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`; crops padding back to ``shape``."""
    mb, nb, b, _ = xb.shape
    x = xb.transpose(0, 2, 1, 3).reshape(mb * b, nb * b)
    return x[: shape[0], : shape[1]]


# ---------------------------------------------------------------------------
# Core block quantization
# ---------------------------------------------------------------------------

def _safe_scale(absmax: jnp.ndarray, levels) -> jnp.ndarray:
    """absmax/L with zero blocks mapped to scale 1 (so q = 0 exactly)."""
    inv = 1.0 / jnp.asarray(levels, jnp.float32)
    return jnp.where(absmax > 0, absmax * inv, 1.0)


def block_quant_ref(x: jnp.ndarray, block: int = 128,
                    levels: float = INT8_L):
    """Per-block round-to-nearest quantization.

    Returns ``(q, scale, absmax)`` where ``q`` is int8-valued (stored f32
    for composability), ``scale``/``absmax`` have shape (M/B, N/B).
    """
    xb = to_blocks(x, block)
    absmax = jnp.max(jnp.abs(xb), axis=(2, 3))
    scale = _safe_scale(absmax, levels)
    q = jnp.clip(jnp.round(xb / scale[:, :, None, None]), -levels, levels)
    return q, scale, absmax


def block_quant_stochastic_ref(x: jnp.ndarray, noise: jnp.ndarray,
                               block: int = 128, levels: float = INT8_L):
    """Per-block *stochastic rounding* quantization (paper §3.1).

    ``noise`` is uniform[0,1) with the same shape as ``x``. x/a is rounded
    to floor(x/a + u): an unbiased estimator, E[Q_s(x)] = x.
    """
    xb = to_blocks(x, block)
    nb = to_blocks(noise, block)
    absmax = jnp.max(jnp.abs(xb), axis=(2, 3))
    scale = _safe_scale(absmax, levels)
    q = jnp.floor(xb / scale[:, :, None, None] + nb)
    q = jnp.clip(q, -levels, levels)
    return q, scale, absmax


def block_dequant_ref(q: jnp.ndarray, scale: jnp.ndarray, shape):
    """Dequantize block representation back to a dense (M, N) matrix."""
    return from_blocks(q * scale[:, :, None, None], shape)


# ---------------------------------------------------------------------------
# Fallback (residual) quantization — paper §4.3
# ---------------------------------------------------------------------------

def fallback_quant_ref(x: jnp.ndarray, theta,
                       block: int = 128, levels: float = INT8_L):
    """Two-step fallback quantization of outlier blocks.

    Returns a dict with
      q, scale      — first-step INT8 block representation
      rq, rscale    — residual INT8 block representation
      u             — (M/B, N/B) {0,1} fallback indicator, AbsMax > theta
      absmax        — first-step block AbsMax (used for threshold control)
    """
    q, scale, absmax = block_quant_ref(x, block, levels)
    xb = to_blocks(x, block)
    resid = xb - q * scale[:, :, None, None]
    rabsmax = jnp.max(jnp.abs(resid), axis=(2, 3))
    rscale = _safe_scale(rabsmax, levels)
    rq = jnp.clip(jnp.round(resid / rscale[:, :, None, None]), -levels, levels)
    u = (absmax > theta).astype(x.dtype)
    return {"q": q, "scale": scale, "rq": rq, "rscale": rscale,
            "u": u, "absmax": absmax}


def fallback_dequant_ref(fq: dict, shape) -> jnp.ndarray:
    """Dequantize the fallback representation (Q + u * ΔQ)."""
    d = fq["q"] * fq["scale"][:, :, None, None]
    d = d + fq["u"][:, :, None, None] * fq["rq"] * fq["rscale"][:, :, None, None]
    return from_blocks(d, shape)


def int16_block_quant_ref(x: jnp.ndarray, block: int = 128):
    """"Double-bit" INT16 comparator for Fig 3(b): one scale, 2^15-1 levels."""
    return block_quant_ref(x, block, levels=32767.0)


# ---------------------------------------------------------------------------
# Block-quantized GEMM (paper Eq. 1) and fallback GEMM (Algorithm 1)
# ---------------------------------------------------------------------------

def block_gemm_ref(qa, sa, qb, sb) -> jnp.ndarray:
    """C = sum_k [Q(A_ik) Q(B_kj)]_int * a_ik * b_kj  (paper Eq. 1).

    qa: (Mb, Kb, B, B) int8-valued blocks of A, sa: (Mb, Kb) scales.
    qb: (Kb, Nb, B, B) int8-valued blocks of B, sb: (Kb, Nb) scales.
    Returns dense (Mb*B, Nb*B) f32 (caller crops padding).

    Int products inside a block accumulate exactly in int32 (the INT8
    TensorCore / MXU path); across K blocks accumulation is f32.
    """
    mb, kb, b, _ = qa.shape
    _, nb, _, _ = qb.shape

    def body(k, acc):
        prod = jnp.einsum(
            "iab,jbc->ijac",
            qa[:, k].astype(jnp.int32), qb[k].astype(jnp.int32),
        ).astype(jnp.float32)
        w = sa[:, k][:, None] * sb[k][None, :]
        return acc + prod * w[:, :, None, None]

    acc = jnp.zeros((mb, nb, b, b), jnp.float32)
    acc = jax.lax.fori_loop(0, kb, body, acc)
    return acc.transpose(0, 2, 1, 3).reshape(mb * b, nb * b)


def fallback_gemm_ref(qa, sa, rqa, rsa, u, qb, sb) -> jnp.ndarray:
    """Algorithm 1: block GEMM + conditional residual accumulation.

    u: (Mb, Kb) {0,1}. The residual product is masked by u — numerically
    identical to the paper's conditional load/compute (the *cost* of the
    conditionality is exercised in the Rust CPU GEMM and the roofline
    cost model; see DESIGN.md §Hardware-Adaptation).
    """
    mb, kb, b, _ = qa.shape
    _, nb, _, _ = qb.shape

    def body(k, acc):
        qbk = qb[k].astype(jnp.int32)
        prod = jnp.einsum("iab,jbc->ijac", qa[:, k].astype(jnp.int32), qbk)
        rprod = jnp.einsum("iab,jbc->ijac", rqa[:, k].astype(jnp.int32), qbk)
        w = sa[:, k][:, None] * sb[k][None, :]
        rw = (u[:, k] * rsa[:, k])[:, None] * sb[k][None, :]
        out = prod.astype(jnp.float32) * w[:, :, None, None]
        out = out + rprod.astype(jnp.float32) * rw[:, :, None, None]
        return acc + out

    acc = jnp.zeros((mb, nb, b, b), jnp.float32)
    acc = jax.lax.fori_loop(0, kb, body, acc)
    return acc.transpose(0, 2, 1, 3).reshape(mb * b, nb * b)


# ---------------------------------------------------------------------------
# 1 x G per-group quantization for non-linear activation contexts (§5.2)
# ---------------------------------------------------------------------------

def group_quant_ref(x: jnp.ndarray, group: int = 128, bits=10.0):
    """1 x ``group`` per-row-group quantization with ``bits``-bit levels.

    ``bits`` may be a traced scalar (runtime-selectable precision): the
    level count L = 2^(bits-1) - 1 only affects values, not shapes.
    Returns (q, scale) with q shaped like x and scale (M, N/G).
    """
    m, n = x.shape
    assert n % group == 0, "channel dim must divide the group size"
    levels = 2.0 ** (jnp.asarray(bits, jnp.float32) - 1.0) - 1.0
    xg = x.reshape(m, n // group, group)
    absmax = jnp.max(jnp.abs(xg), axis=2)
    scale = _safe_scale(absmax, levels)
    q = jnp.clip(jnp.round(xg / scale[:, :, None]), -levels, levels)
    return q.reshape(m, n), scale


def group_dequant_ref(q: jnp.ndarray, scale: jnp.ndarray, group: int = 128):
    m, n = q.shape
    qg = q.reshape(m, n // group, group)
    return (qg * scale[:, :, None]).reshape(m, n)


# ---------------------------------------------------------------------------
# Fallback-criterion metrics (§4.4): AbsMax / L1 / L1-Rel per block
# ---------------------------------------------------------------------------

def criterion_metrics_ref(x: jnp.ndarray, block: int = 128,
                          levels: float = INT8_L):
    """Per-block values of the three candidate fallback criteria.

    Returns dict of (M/B, N/B) arrays: absmax, l1 (absolute quantization
    error), l1rel (relative quantization error).
    """
    q, scale, absmax = block_quant_ref(x, block, levels)
    xb = to_blocks(x, block)
    err = jnp.sum(jnp.abs(xb - q * scale[:, :, None, None]), axis=(2, 3))
    tot = jnp.sum(jnp.abs(xb), axis=(2, 3))
    l1rel = jnp.where(tot > 0, err / tot, 0.0)
    return {"absmax": absmax, "l1": err, "l1rel": l1rel}


# ---------------------------------------------------------------------------
# Convenience end-to-end quantized matmuls (used by tests and the L2 model)
# ---------------------------------------------------------------------------

def quantized_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, block: int = 128,
                         levels: float = INT8_L) -> jnp.ndarray:
    """Plain block-quantized A @ B (both round-to-nearest)."""
    qa, sa, _ = block_quant_ref(a, block, levels)
    qbm, sbm, _ = block_quant_ref(b, block, levels)
    c = block_gemm_ref(qa, sa, qbm, sbm)
    return c[: a.shape[0], : b.shape[1]]


def fallback_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                        theta, block: int = 128,
                        levels: float = INT8_L):
    """Fallback A (per Alg 1) times block-quantized B; returns (C, rate)."""
    fa = fallback_quant_ref(a, theta, block, levels)
    qbm, sbm, _ = block_quant_ref(b, block, levels)
    c = fallback_gemm_ref(fa["q"], fa["scale"], fa["rq"], fa["rscale"],
                          fa["u"], qbm, sbm)
    rate = jnp.mean(fa["u"])
    return c[: a.shape[0], : b.shape[1]], rate
