"""L1 Pallas kernels for DBFQ (interpret=True; see DESIGN.md).

Modules:
  ref           — pure-jnp oracles (also reused by the L2 model)
  block_quant   — block / stochastic / fused-fallback quantization kernels
  fallback_gemm — Algorithm 1 mixed-precision GEMM + plain block GEMM
  group_quant   — 1 x 128 n-bit context compression kernels
"""

from . import ref  # noqa: F401
from . import block_quant  # noqa: F401
from . import fallback_gemm  # noqa: F401
from . import group_quant  # noqa: F401
