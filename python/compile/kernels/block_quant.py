"""L1 Pallas kernels: block quantization (round-to-nearest / stochastic).

One grid step handles one ``B x B`` quantization block: the block is the
Pallas BlockSpec unit, so the HBM→VMEM schedule *is* the quantization
grouping (DESIGN.md §Hardware-Adaptation). All kernels run with
``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is asserted against :mod:`ref` by pytest.

VMEM per grid step (B = 128, f32 staging): in-block 64 KiB + out q-block
64 KiB + scalars — far below the ~16 MiB budget, leaving headroom for
double-buffering on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INT8_L = ref.INT8_L


def _quant_kernel(x_ref, q_ref, s_ref, m_ref, *, levels: float):
    """Round-to-nearest INT8 quantization of one block."""
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax * (1.0 / levels), 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -levels, levels)
    s_ref[0, 0] = scale
    m_ref[0, 0] = absmax


def _quant_stochastic_kernel(x_ref, n_ref, q_ref, s_ref, m_ref, *,
                             levels: float):
    """Stochastic-rounding INT8 quantization of one block.

    ``n_ref`` holds uniform[0,1) noise; q = floor(x/a + u) is unbiased.
    """
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax * (1.0 / levels), 1.0)
    q = jnp.floor(x / scale + n_ref[...])
    q_ref[...] = jnp.clip(q, -levels, levels)
    s_ref[0, 0] = scale
    m_ref[0, 0] = absmax


def _fallback_kernel(x_ref, t_ref, q_ref, s_ref, rq_ref, rs_ref, u_ref,
                     m_ref, *, levels: float):
    """Two-step fallback quantization of one block (paper §4.3).

    Step 1 quantizes the block; step 2 quantizes the residual. The
    fallback indicator u = [absmax > theta] is emitted per block so the
    GEMM kernel (and the Rust coordinator's threshold controller) can
    consume it.
    """
    x = x_ref[...]
    theta = t_ref[0, 0]
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax * (1.0 / levels), 1.0)
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    resid = x - q * scale
    rabsmax = jnp.max(jnp.abs(resid))
    rscale = jnp.where(rabsmax > 0, rabsmax * (1.0 / levels), 1.0)
    rq = jnp.clip(jnp.round(resid / rscale), -levels, levels)
    q_ref[...] = q
    s_ref[0, 0] = scale
    rq_ref[...] = rq
    rs_ref[0, 0] = rscale
    u_ref[0, 0] = (absmax > theta).astype(x.dtype)
    m_ref[0, 0] = absmax


def _grid2d(m: int, n: int, block: int):
    assert m % block == 0 and n % block == 0, \
        f"block_quant kernels need block-aligned shapes, got {(m, n)}"
    return (m // block, n // block)


@functools.partial(jax.jit, static_argnames=("block", "levels"))
def block_quant(x: jnp.ndarray, block: int = 128, levels: float = INT8_L):
    """Pallas round-to-nearest block quantization.

    Returns (q, scale, absmax): q int8-valued f32 (M, N); scale/absmax
    (M/B, N/B). Matches :func:`ref.block_quant_ref` exactly (pytest).
    """
    m, n = x.shape
    grid = _grid2d(m, n, block)
    blk = pl.BlockSpec((block, block), lambda i, j: (i, j))
    scl = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    q, s, am = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels),
        grid=grid,
        in_specs=[blk],
        out_specs=[blk, scl, scl],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
        ],
        interpret=True,
    )(x)
    return q, s, am


@functools.partial(jax.jit, static_argnames=("block", "levels"))
def block_quant_stochastic(x: jnp.ndarray, noise: jnp.ndarray,
                           block: int = 128, levels: float = INT8_L):
    """Pallas stochastic-rounding block quantization (q, scale, absmax)."""
    m, n = x.shape
    grid = _grid2d(m, n, block)
    blk = pl.BlockSpec((block, block), lambda i, j: (i, j))
    scl = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    q, s, am = pl.pallas_call(
        functools.partial(_quant_stochastic_kernel, levels=levels),
        grid=grid,
        in_specs=[blk, blk],
        out_specs=[blk, scl, scl],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
        ],
        interpret=True,
    )(x, noise)
    return q, s, am


@functools.partial(jax.jit, static_argnames=("block", "levels"))
def fallback_quant(x: jnp.ndarray, theta: jnp.ndarray, block: int = 128,
                   levels: float = INT8_L):
    """Pallas fused fallback quantization (paper §5.3: "fuse dynamic
    fallback quantization into a quantization kernel").

    theta: scalar threshold (traced — runtime-adjustable by the Rust
    delay-threshold controller without recompilation).
    Returns dict matching :func:`ref.fallback_quant_ref`.
    """
    m, n = x.shape
    grid = _grid2d(m, n, block)
    blk = pl.BlockSpec((block, block), lambda i, j: (i, j))
    scl = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    tsp = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    theta_arr = jnp.asarray(theta, x.dtype).reshape(1, 1)
    q, s, rq, rs, u, am = pl.pallas_call(
        functools.partial(_fallback_kernel, levels=levels),
        grid=grid,
        in_specs=[blk, tsp],
        out_specs=[blk, scl, blk, scl, scl, scl],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
            jax.ShapeDtypeStruct((grid[0], grid[1]), x.dtype),
        ],
        interpret=True,
    )(x, theta_arr)
    return {"q": q, "scale": s, "rq": rq, "rscale": rs, "u": u, "absmax": am}
