"""AOT driver: lower every L2 entry point to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format — the
``xla`` crate's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Emits ``artifacts/<name>.hlo.txt`` plus ``artifacts/manifest.json``
describing every artifact's I/O signature and each model profile's
parameter layout, so the Rust runtime is fully self-describing.

Profiles:
  tiny   — test-sized model (fast; used by cargo test + quickstart)
  probe  — ablation model for gradient-cosine sweeps (Figs 3c/5/7a)
  small  — pretraining-comparison model (Fig 7b/8, Table 4)
  e2e    — ~100M-parameter model for the end-to-end example

Python runs only here (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantized as Q
from . import trainstep as T
from .kernels import block_quant as kbq
from .kernels import fallback_gemm as kfg
from .kernels import group_quant as kgq

MODES = [Q.BF16, Q.BLOCK, Q.FALLBACK, Q.JETFIRE]


@dataclasses.dataclass(frozen=True)
class Profile:
    name: str
    mcfg: M.ModelConfig
    batch: int
    block: int
    group: int


PROFILES = {
    "tiny": Profile(
        "tiny",
        M.ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=2,
                      d_ff=128, seq_len=32),
        batch=2, block=16, group=16),
    "probe": Profile(
        "probe",
        M.ModelConfig(vocab=256, d_model=256, n_layers=4, n_heads=4,
                      d_ff=1024, seq_len=128),
        batch=2, block=128, group=128),
    "small": Profile(
        "small",
        M.ModelConfig(vocab=256, d_model=384, n_layers=6, n_heads=6,
                      d_ff=1536, seq_len=256),
        batch=2, block=128, group=128),
    "e2e": Profile(
        "e2e",
        M.ModelConfig(vocab=256, d_model=768, n_layers=12, n_heads=12,
                      d_ff=3072, seq_len=256),
        batch=2, block=128, group=128),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(s):
    return str(s.dtype)


class Emitter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.manifest = {"artifacts": {}, "profiles": {}}

    def emit(self, name: str, fn, specs, input_names, output_names):
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *specs)
        outs = jax.tree.leaves(out_avals)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s.shape), "dtype": _dt(s)}
                       for n, s in zip(input_names, specs)],
            "outputs": [{"name": n, "shape": list(o.shape), "dtype": _dt(o)}
                        for n, o in zip(output_names, outs)],
        }
        print(f"  wrote {name}: {len(text)/1e6:.2f} MB")

    def profile_meta(self, prof: Profile):
        layout, n_params = M.param_layout(prof.mcfg)
        mc = prof.mcfg
        self.manifest["profiles"][prof.name] = {
            "model": {
                "vocab": mc.vocab, "d_model": mc.d_model,
                "n_layers": mc.n_layers, "n_heads": mc.n_heads,
                "d_ff": mc.d_ff, "seq_len": mc.seq_len, "glu": mc.glu,
            },
            "batch": prof.batch, "block": prof.block, "group": prof.group,
            "n_params": n_params,
            "n_sites": 4 * mc.n_layers + 1,
            "param_layout": layout,
        }

    def save_manifest(self):
        with open(os.path.join(self.outdir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def emit_profile(em: Emitter, prof: Profile, modes, train=True,
                 eval_=True, prefix_eval=False, probe=False,
                 act_probe=False, blocksize_sweep=False,
                 nonglu=False):
    mc = prof.mcfg
    em.profile_meta(prof)
    P = mc.n_params()
    n_sites = 4 * mc.n_layers + 1
    tok = _spec((prof.batch, mc.seq_len + 1), jnp.int32)
    theta = _spec((n_sites,))
    qs = _spec((len(T.QSCALAR_NAMES),))
    pv = _spec((P,))

    # init (mode-independent)
    em.emit(f"init_{prof.name}", T.make_init(mc),
            [_spec((), jnp.int32)], ["seed"], ["params"])

    for mode in modes:
        qcfg = Q.QuantConfig(
            mode=mode,
            block=32 if mode == Q.JETFIRE else prof.block,
            group=prof.group,
            nonlinear_int8=(mode == Q.JETFIRE))
        tag = f"{prof.name}_{mode}"
        if train:
            em.emit(
                f"train_{tag}", T.make_train_step(qcfg, mc),
                [pv, pv, pv, _spec(()), tok, _spec((), jnp.int32),
                 theta, qs, _spec((3,))],
                ["params", "m", "v", "step", "tokens", "seed", "theta",
                 "qscalars", "opt"],
                ["params", "m", "v", "loss", "rates", "grad_norm"])
        if eval_:
            em.emit(
                f"eval_{tag}", T.make_eval_step(qcfg, mc),
                [pv, tok, theta, qs],
                ["params", "tokens", "theta", "qscalars"],
                ["loss", "per_token_loss", "rates"])
        if prefix_eval and mode != Q.BF16:
            em.emit(
                f"evalp_{tag}", T.make_eval_step(qcfg, mc, with_prefix=True),
                [pv, _spec((1, mc.seq_len + 1), jnp.int32), theta, qs,
                 _spec((), jnp.int32)],
                ["params", "tokens", "theta", "qscalars", "prefix_len"],
                ["loss", "per_token_loss", "rates"])
        if probe:
            em.emit(
                f"grads_{tag}", T.make_probe_grads(qcfg, mc),
                [pv, tok, _spec((), jnp.int32), theta, qs],
                ["params", "tokens", "seed", "theta", "qscalars"],
                ["loss", "grads", "rates"])

    if act_probe:
        # Capture the DownProj input (GLU output) of the last layer in
        # *unquantized* form — feeds the outlier analyses (Fig 2c, 4a).
        qcfg = Q.QuantConfig(mode=Q.BF16, block=prof.block, group=prof.group)
        em.emit(
            f"act_{prof.name}",
            T.make_activation_probe(qcfg, mc, mc.n_layers - 1),
            [pv, tok, theta, qs],
            ["params", "tokens", "theta", "qscalars"],
            ["act"])

    if nonglu:
        # Matched non-GLU (GELU) variant for Table 1 / Fig 2 comparisons.
        mc_ng = dataclasses.replace(mc, glu=False, d_ff=2 * mc.d_ff)
        prof_ng = Profile(prof.name + "_nonglu", mc_ng, prof.batch,
                          prof.block, prof.group)
        em.profile_meta(prof_ng)
        P_ng = mc_ng.n_params()
        pv_ng = _spec((P_ng,))
        em.emit(f"init_{prof_ng.name}", T.make_init(mc_ng),
                [_spec((), jnp.int32)], ["seed"], ["params"])
        qcfg = Q.QuantConfig(mode=Q.BF16, block=prof.block, group=prof.group)
        em.emit(
            f"train_{prof_ng.name}_bf16", T.make_train_step(qcfg, mc_ng),
            [pv_ng, pv_ng, pv_ng, _spec(()), tok, _spec((), jnp.int32),
             theta, qs, _spec((3,))],
            ["params", "m", "v", "step", "tokens", "seed", "theta",
             "qscalars", "opt"],
            ["params", "m", "v", "loss", "rates", "grad_norm"])
        em.emit(
            f"act_{prof_ng.name}",
            T.make_activation_probe(qcfg, mc_ng, mc_ng.n_layers - 1),
            [pv_ng, tok, theta, qs],
            ["params", "tokens", "theta", "qscalars"],
            ["act"])

    if blocksize_sweep:
        # Fig 4(b): PPL vs quantization block size, naive vs fallback.
        for bs in [32, 64, 128, 256]:
            for mode in [Q.BLOCK, Q.FALLBACK]:
                qcfg = Q.QuantConfig(mode=mode, block=bs, group=prof.group)
                em.emit(
                    f"eval_{prof.name}_{mode}_bs{bs}",
                    T.make_eval_step(qcfg, mc),
                    [pv, tok, theta, qs],
                    ["params", "tokens", "theta", "qscalars"],
                    ["loss", "per_token_loss", "rates"])


def emit_kernel_ops(em: Emitter):
    """Op-level artifacts lowered from the *actual Pallas kernels* —
    executed by the Rust runtime tests to prove the L1→L3 path and to
    cross-validate the Rust quant/gemm implementations bitwise."""
    m, n, k, b = 64, 48, 80, 16
    mb, nb, kb = m // b, n // b, k // b

    def fb_gemm_op(qa, sa, rqa, rsa, u, qb, sb):
        return kfg.fallback_gemm(qa, sa, rqa, rsa, u, qb, sb, block=b)

    em.emit("op_fallback_gemm", fb_gemm_op,
            [_spec((m, k)), _spec((mb, kb)), _spec((m, k)), _spec((mb, kb)),
             _spec((mb, kb)), _spec((k, n)), _spec((kb, nb))],
            ["qa", "sa", "rqa", "rsa", "u", "qb", "sb"], ["c"])

    def bq_op(x, theta):
        return kbq.fallback_quant(x, theta, block=b)

    em.emit("op_fallback_quant", bq_op,
            [_spec((m, k)), _spec(())],
            ["x", "theta"],
            ["absmax", "q", "rq", "rscale", "scale", "u"])  # dict sorted

    def gq_op(x, bits):
        return kgq.group_quant(x, bits, group=16)

    em.emit("op_group_quant", gq_op,
            [_spec((m, k)), _spec(())],
            ["x", "bits"], ["q", "scale"])

    def block_gemm_op(qa, sa, qb, sb):
        return kfg.block_gemm(qa, sa, qb, sb, block=b)

    em.emit("op_block_gemm", block_gemm_op,
            [_spec((m, k)), _spec((mb, kb)), _spec((k, n)), _spec((kb, nb))],
            ["qa", "sa", "qb", "sb"], ["c"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profiles", default="tiny,probe,small,e2e",
                    help="comma list; e2e lowers ~100M-param graphs")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    em = Emitter(args.out)

    wanted = args.profiles.split(",")

    if "tiny" in wanted:
        print("profile tiny")
        emit_profile(em, PROFILES["tiny"], MODES, train=True, eval_=True,
                     prefix_eval=True, probe=True, act_probe=True,
                     nonglu=True)
    if "probe" in wanted:
        print("profile probe")
        emit_profile(em, PROFILES["probe"], [Q.FALLBACK], train=False,
                     eval_=False, probe=True)
    if "small" in wanted:
        print("profile small")
        emit_profile(em, PROFILES["small"], MODES, train=True, eval_=True,
                     prefix_eval=True, probe=False, act_probe=True,
                     blocksize_sweep=True, nonglu=True)
    if "e2e" in wanted:
        print("profile e2e")
        emit_profile(em, PROFILES["e2e"], [Q.BF16, Q.FALLBACK], train=True,
                     eval_=True)

    print("kernel ops")
    emit_kernel_ops(em)
    em.save_manifest()
    print("manifest written")


if __name__ == "__main__":
    main()
