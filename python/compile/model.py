"""L2 model: Llama-style transformer with GLU, built on quantized ops.

Architecture (paper §6.2 pretraining config, scaled): token embedding →
N pre-norm blocks (RMSNorm → MHA with RoPE → RMSNorm → SwiGLU MLP) →
final RMSNorm → LM head. A ``glu=False`` variant (GELU MLP, GPT-2-style)
supports the paper's GLU-vs-non-GLU outlier analysis (Table 1, Fig 2).

Every linear layer is a :func:`quantized.quantized_linear` *site*; sites
are numbered (layer, j) with j ∈ {0: attn-in, 1: attn-out, 2: mlp-in,
3: mlp-down} plus one LM-head site, matching the per-layer fallback
thresholds θ the Rust delay-threshold controller maintains (Alg 2).

Layers are stacked and scanned (homogeneous pytrees), keeping the lowered
HLO compact regardless of depth. Attention stays in high precision
(paper §5.3: FlashAttention is kept BF16 — not part of the contribution).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import quantized as Q


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256            # byte-level tokenizer (data pipeline, L3)
    d_model: int = 512
    n_layers: int = 8
    n_heads: int = 8
    d_ff: int = 2048            # GLU intermediate size
    seq_len: int = 256
    glu: bool = True            # False -> GELU MLP (GPT-2-style)
    tie_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        leaves, _ = _shape_leaves(param_shapes(self))
        total = 0
        for shape in leaves:
            size = 1
            for s in shape:
                size *= int(s)
            total += size
        return total


def _is_shape(x) -> bool:
    return isinstance(x, tuple)


def _shape_leaves(shapes):
    return jax.tree.flatten(shapes, is_leaf=_is_shape)


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """Pytree of parameter shapes (stacked per-layer leading dim)."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    mlp_in = (2 * f, d) if cfg.glu else (f, d)
    shapes = {
        "emb": (cfg.vocab, d),
        "blocks": {
            "ln1": (L, d),
            "wqkv": (L, 3 * d, d),
            "wo": (L, d, d),
            "ln2": (L, d),
            "win": (L,) + mlp_in,
            "wdown": (L, d, f),
        },
        "ln_f": (d,),
    }
    if not cfg.tie_embeddings:
        shapes["head"] = (cfg.vocab, d)
    return shapes


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    """GPT-2-style init: N(0, 0.02), residual projections scaled 1/sqrt(2L)."""
    shapes = param_shapes(cfg)
    leaves, treedef = _shape_leaves(shapes)
    keys = jax.random.split(key, len(leaves))
    std = 0.02
    resid_std = std / jnp.sqrt(2.0 * cfg.n_layers)

    flat_names = _leaf_names(shapes)
    out = []
    for k, shape, name in zip(keys, leaves, flat_names):
        if name.endswith("ln1") or name.endswith("ln2") or name.endswith("ln_f"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("wo") or name.endswith("wdown"):
            out.append(jax.random.normal(k, shape, jnp.float32) * resid_std)
        else:
            out.append(jax.random.normal(k, shape, jnp.float32) * std)
    return jax.tree.unflatten(treedef, out)


def _leaf_names(tree, prefix=""):
    """Deterministic dotted names for pytree leaves (dict keys sorted)."""
    names = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            names.extend(_leaf_names(tree[k], prefix + k + "."))
    else:
        assert _is_shape(tree)
        names.append(prefix[:-1])
    return names


def param_layout(cfg: ModelConfig):
    """(name, shape, offset) table for the flat f32 parameter vector.

    The Rust runtime uses this layout (via the artifact manifest) to
    inspect or checkpoint parameters without Python.
    """
    shapes = param_shapes(cfg)
    leaves, _ = _shape_leaves(shapes)
    names = _leaf_names(shapes)
    layout, off = [], 0
    for name, shape in zip(names, leaves):
        size = 1
        for s in shape:
            size *= int(s)
        layout.append({"name": name, "shape": list(shape), "offset": off,
                       "size": size})
        off += size
    return layout, off


def flatten_params(params) -> jnp.ndarray:
    return jnp.concatenate([p.reshape(-1) for p in jax.tree.leaves(params)])


def unflatten_params(cfg: ModelConfig, flat: jnp.ndarray):
    shapes = param_shapes(cfg)
    leaves, treedef = _shape_leaves(shapes)
    out, off = [], 0
    for shape in leaves:
        size = 1
        for s in shape:
            size *= int(s)
        out.append(flat[off: off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over (B, T, H, Dh)."""
    _, t, _, dh = x.shape
    half = dh // 2
    freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)  # (T, half)
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _attention(q, k, v, head_dim):
    """Causal MHA in high precision (paper keeps attention BF16)."""
    att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(head_dim))
    t = q.shape[1]
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", att, v)


def _block_apply(qcfg: Q.QuantConfig, mcfg: ModelConfig, x, blk, theta_row,
                 qp, key, quant_prefix_len=None):
    """One transformer block; returns (x, rates(4,))."""
    b, t, d = x.shape
    nh, hd = mcfg.n_heads, mcfg.head_dim
    k0, k1, k2, k3 = jax.random.split(key, 4)

    h = rmsnorm_masked(qcfg, x, blk["ln1"], qp, quant_prefix_len)
    qkv, r0 = Q.quantized_linear(qcfg, h, blk["wqkv"], qp, theta_row[0], k0)
    qkv = qkv.reshape(b, t, 3, nh, hd)
    qh = _rope(qkv[:, :, 0])
    kh = _rope(qkv[:, :, 1])
    vh = qkv[:, :, 2]
    a = _attention(qh, kh, vh, hd).reshape(b, t, d)
    ao, r1 = Q.quantized_linear(qcfg, a, blk["wo"], qp, theta_row[1], k1)
    x = x + ao

    h = rmsnorm_masked(qcfg, x, blk["ln2"], qp, quant_prefix_len)
    hin, r2 = Q.quantized_linear(qcfg, h, blk["win"], qp, theta_row[2], k2)
    if mcfg.glu:
        g, u = jnp.split(hin, 2, axis=-1)
        act = Q.swiglu_ctx(qcfg, g, u, qp)
    else:
        act = Q.gelu_ctx(qcfg, hin, qp)
    mo, r3 = Q.quantized_linear(qcfg, act, blk["wdown"], qp, theta_row[3], k3)
    x = x + mo
    return x, jnp.stack([r0, r1, r2, r3])


def rmsnorm_masked(qcfg, x, gamma, qp, prefix_len):
    """RMSNorm with context compression; optionally zero-mask tokens
    beyond ``prefix_len`` *before* quantization (no-leakage eval,
    Table 4: quantization scales must not see future tokens)."""
    if prefix_len is not None:
        t = x.shape[1]
        keep = (jnp.arange(t) < prefix_len)[None, :, None]
        x = jnp.where(keep, x, 0.0)
    return Q.rmsnorm_ctx(qcfg, x, gamma, qp)


def forward(qcfg: Q.QuantConfig, mcfg: ModelConfig, params, tokens, qp,
            key, quant_prefix_len=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Logits + per-site fallback rates.

    tokens: (B, T) int32. Returns (logits (B, T, V), rates (L, 4) ++ head).
    """
    x = params["emb"][tokens]
    blocks = params["blocks"]
    n_l = mcfg.n_layers
    keys = jax.random.split(key, n_l + 1)

    def body(x, per_layer):
        blk, theta_row, k = per_layer
        x, rates = _block_apply(qcfg, mcfg, x, blk, theta_row, qp, k,
                                quant_prefix_len)
        return x, rates

    per_layer = (blocks, qp["theta"], keys[:n_l])
    x, rates = jax.lax.scan(body, x, per_layer)

    x = rmsnorm_masked(qcfg, x, params["ln_f"], qp, quant_prefix_len)
    w_head = params["emb"] if mcfg.tie_embeddings else params["head"]
    logits, r_head = Q.quantized_linear(qcfg, x, w_head, qp,
                                        qp["theta_head"], keys[n_l])
    all_rates = jnp.concatenate([rates.reshape(-1), r_head.reshape(1)])
    return logits, all_rates


def loss_fn(qcfg, mcfg, params, tokens, targets, qp, key,
            quant_prefix_len=None):
    """Mean next-token cross-entropy; returns (loss, (rates, per_tok))."""
    logits, rates = forward(qcfg, mcfg, params, tokens, qp, key,
                            quant_prefix_len)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    per_tok = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(per_tok), (rates, per_tok)
