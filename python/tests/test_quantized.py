"""L2 quantized-op tests: custom VJPs, scale-factored GEMM equivalence,
context compression, and the ablation switches."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import quantized as Q
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(shape, seed=0, scale=1.0, outliers=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32) * scale
    if outliers:
        idx = rng.integers(0, x.size, size=outliers)
        x.flat[idx] *= 100.0
    return jnp.asarray(x)


CFG = Q.QuantConfig(mode=Q.FALLBACK, block=16, group=16)
KEY = jax.random.PRNGKey(0)


def qparams(**over):
    qp = Q.default_qparams(1)
    qp.update(over)
    return qp


# ---------------------------------------------------------------------------
# scale-factored GEMM == exact Eq. 1 kernel path
# ---------------------------------------------------------------------------

def test_scale_factored_equals_exact_block_gemm():
    """deq(A) @ deq(B) must equal the exact int32 block GEMM to f32
    rounding — the argument that lets the L2 graph use dense matmuls."""
    a = rand((32, 48), seed=1, outliers=4)
    b = rand((48, 32), seed=2)
    qa, sa, _ = ref.block_quant_ref(a, 16)
    qb, sb, _ = ref.block_quant_ref(b, 16)
    exact = ref.block_gemm_ref(qa, sa, qb, sb)[:32, :32]
    fast = (ref.block_dequant_ref(qa, sa, a.shape)
            @ ref.block_dequant_ref(qb, sb, b.shape))
    np.testing.assert_allclose(np.asarray(fast), np.asarray(exact),
                               rtol=2e-5, atol=1e-3)


def test_scale_factored_equals_exact_fallback_gemm():
    a = rand((32, 48), seed=3, outliers=6)
    b = rand((48, 32), seed=4)
    fa = ref.fallback_quant_ref(a, 2.0, 16)
    qb, sb, _ = ref.block_quant_ref(b, 16)
    exact = ref.fallback_gemm_ref(fa["q"], fa["scale"], fa["rq"],
                                  fa["rscale"], fa["u"], qb, sb)[:32, :32]
    fast = (ref.fallback_dequant_ref(fa, a.shape)
            @ ref.block_dequant_ref(qb, sb, b.shape))
    np.testing.assert_allclose(np.asarray(fast), np.asarray(exact),
                               rtol=2e-5, atol=1e-3)


def test_int8_products_exact_in_f32():
    """127^2 * 1024 < 2^24: the block-product exactness bound."""
    assert 127 * 127 * 1024 < 2 ** 24
    # adversarial worst case: all-127 codes at block 16
    q = jnp.full((16, 16), 127.0)
    exact = int(127) * 127 * 16
    fast = float((q @ q.T)[0, 0])
    assert fast == float(exact)


# ---------------------------------------------------------------------------
# quantized_linear forward/backward
# ---------------------------------------------------------------------------

def test_linear_fwd_matches_manual():
    x = rand((32, 64), seed=5, outliers=3)
    w = rand((48, 64), seed=6, scale=0.1)
    qp = qparams()
    y, rate = Q.quantized_linear(CFG, x, w, qp, jnp.float32(1.0), KEY)
    # manual: fallback-quant X, RTN W^T, scale-factored matmul
    fx = ref.fallback_quant_ref(x, jnp.inf, 16)
    fx["u"] = (ref.criterion_metrics_ref(x, 16)["absmax"] > 1.0).astype(
        jnp.float32)
    qw, sw, _ = ref.block_quant_ref(w.T, 16)
    want = (ref.fallback_dequant_ref(fx, x.shape)
            @ ref.block_dequant_ref(qw, sw, w.T.shape))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)
    assert float(rate) == float(jnp.mean(fx["u"]))


def test_linear_bf16_is_exact():
    cfg = Q.QuantConfig(mode=Q.BF16, block=16, group=16)
    x = rand((8, 32), seed=7)
    w = rand((16, 32), seed=8)
    y, rate = Q.quantized_linear(cfg, x, w, qparams(), jnp.float32(1.0),
                                 KEY)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T),
                               rtol=1e-6)
    assert float(rate) == 0.0


@pytest.mark.parametrize("mode", [Q.BF16, Q.BLOCK, Q.FALLBACK])
def test_linear_grads_close_to_exact(mode):
    cfg = Q.QuantConfig(mode=mode, block=16, group=16)
    x = rand((32, 64), seed=9)
    w = rand((48, 64), seed=10, scale=0.1)
    qp = qparams()

    def loss(x, w):
        y, _ = Q.quantized_linear(cfg, x, w, qp, jnp.float32(1e9), KEY)
        return jnp.sum(y ** 2)

    g = jax.grad(loss, argnums=(0, 1))(x, w)
    ge = jax.grad(lambda x, w: jnp.sum((x @ w.T) ** 2),
                  argnums=(0, 1))(x, w)
    for gg, gge in zip(g, ge):
        cos = float(jnp.sum(gg * gge)
                    / (jnp.linalg.norm(gg) * jnp.linalg.norm(gge)))
        tol = 0.995 if mode != Q.BF16 else 1.0 - 1e-6
        assert cos > tol, f"{mode}: cos={cos}"


def test_fallback_improves_forward_with_outliers():
    x = rand((32, 64), seed=11, outliers=8)
    w = rand((48, 64), seed=12, scale=0.1)
    qp = qparams()
    exact = x @ w.T
    y_fb, rate = Q.quantized_linear(CFG, x, w, qp, jnp.float32(1.0), KEY)
    cfg_blk = Q.QuantConfig(mode=Q.BLOCK, block=16, group=16)
    y_blk, _ = Q.quantized_linear(cfg_blk, x, w, qp, jnp.float32(1.0), KEY)
    e_fb = float(jnp.linalg.norm(y_fb - exact))
    e_blk = float(jnp.linalg.norm(y_blk - exact))
    assert rate > 0
    assert e_fb < e_blk, f"{e_fb} !< {e_blk}"


def test_sr_switch_changes_grads_deterministically():
    x = rand((32, 64), seed=13)
    w = rand((48, 64), seed=14, scale=0.1)

    def gw(sr):
        qp = qparams(sr_dy=jnp.float32(sr))
        def loss(w):
            y, _ = Q.quantized_linear(CFG, x, w, qp, jnp.float32(1e9), KEY)
            return jnp.sum(y ** 2)
        return jax.grad(loss)(w)

    g1 = gw(1.0)
    g1b = gw(1.0)
    g0 = gw(0.0)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g1b))
    assert not np.array_equal(np.asarray(g1), np.asarray(g0))


def test_fallback_bwd_switch():
    """fallback_bwd=1 stores 16-bit X context -> better dW cosine."""
    x = rand((32, 64), seed=15, outliers=10)
    w = rand((48, 64), seed=16, scale=0.1)
    ge = jax.grad(lambda w: jnp.sum((x @ w.T) ** 2))(w)

    def gw(fb):
        qp = qparams(fallback_bwd=jnp.float32(fb),
                     sr_ctx=jnp.float32(0.0))
        def loss(w):
            y, _ = Q.quantized_linear(CFG, x, w, qp, jnp.float32(-1.0),
                                      KEY)
            return jnp.sum(y ** 2)
        return jax.grad(loss)(w)

    cos = lambda a, b: float(jnp.sum(a * b)
                             / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    c16 = cos(gw(1.0), ge)
    c8 = cos(gw(0.0), ge)
    assert c16 >= c8 - 1e-4, f"16-bit ctx {c16} vs 8-bit {c8}"


# ---------------------------------------------------------------------------
# non-linear context ops
# ---------------------------------------------------------------------------

def test_rmsnorm_forward_unaffected_by_ctx_bits():
    x = rand((4, 8, 16), seed=17)
    gamma = jnp.ones((16,))
    y1 = Q.rmsnorm_ctx(CFG, x, gamma, qparams(ctx_bits=jnp.float32(4.0)))
    y2 = Q.rmsnorm_ctx(CFG, x, gamma, qparams(ctx_bits=jnp.float32(12.0)))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(x / rms),
                               rtol=1e-5)


def test_rmsnorm_grad_improves_with_ctx_bits():
    x = rand((2, 8, 32), seed=18, outliers=4)
    gamma = rand((32,), seed=19, scale=0.5) + 1.0
    cfg = Q.QuantConfig(mode=Q.FALLBACK, block=16, group=32)

    def gx(bits):
        qp = qparams(ctx_bits=jnp.float32(bits))
        return jax.grad(
            lambda x: jnp.sum(Q.rmsnorm_ctx(cfg, x, gamma, qp) ** 2))(x)

    ge = jax.grad(lambda x: jnp.sum(
        (x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
         * gamma) ** 2))(x)
    cos = lambda a, b: float(jnp.sum(a * b)
                             / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    cs = [cos(gx(b), ge) for b in [2.0, 4.0, 8.0, 12.0]]
    assert cs[-1] > cs[0]
    assert cs[-1] > 0.999, f"cosines {cs}"


def test_swiglu_forward_and_grad():
    g = rand((2, 8, 32), seed=20)
    u = rand((2, 8, 32), seed=21)
    cfg = Q.QuantConfig(mode=Q.FALLBACK, block=16, group=32)
    qp = qparams()
    y = Q.swiglu_ctx(cfg, g, u, qp)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jax.nn.silu(g) * u), rtol=1e-6)
    gg, gu = jax.grad(
        lambda g, u: jnp.sum(Q.swiglu_ctx(cfg, g, u, qp) ** 2),
        argnums=(0, 1))(g, u)
    gge, gue = jax.grad(
        lambda g, u: jnp.sum((jax.nn.silu(g) * u) ** 2),
        argnums=(0, 1))(g, u)
    cos = lambda a, b: float(jnp.sum(a * b)
                             / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    assert cos(gg, gge) > 0.999
    assert cos(gu, gue) > 0.999


def test_jetfire_int8_dataflow_degrades_nonlinear_grads():
    """Fig 6a's point: INT8 non-linear contexts hurt more than INT10."""
    x = rand((2, 8, 32), seed=22, outliers=6)
    gamma = jnp.ones((32,))
    jet = Q.QuantConfig(mode=Q.JETFIRE, block=32, group=32,
                        nonlinear_int8=True)
    ours = Q.QuantConfig(mode=Q.FALLBACK, block=16, group=32)
    qp = qparams()
    # random projection loss (||rmsnorm(x)||^2 is constant -> zero grad)
    proj = rand((2, 8, 32), seed=23)
    ge = jax.grad(lambda x: jnp.sum(
        proj * (x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6))
    ))(x)
    cos = lambda a, b: float(jnp.sum(a * b)
                             / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    gj = jax.grad(lambda x: jnp.sum(
        proj * Q.rmsnorm_ctx(jet, x, gamma, qp)))(x)
    go = jax.grad(lambda x: jnp.sum(
        proj * Q.rmsnorm_ctx(ours, x, gamma, qp)))(x)
    assert cos(go, ge) >= cos(gj, ge) - 1e-5
