"""L2 model tests: shapes, parameter layout, loss behaviour, train step."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M, quantized as Q, trainstep as T

jax.config.update("jax_enable_x64", False)

MCFG = M.ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=2,
                     d_ff=128, seq_len=32)
QCFG = Q.QuantConfig(mode=Q.FALLBACK, block=16, group=16)


def toks(batch, seq, seed=0, vocab=64):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, seq),
                              0, vocab)


def qp_default():
    qp = Q.default_qparams(MCFG.n_layers)
    return qp


def test_param_layout_consistent():
    layout, total = M.param_layout(MCFG)
    assert total == MCFG.n_params()
    # offsets are contiguous and ordered
    off = 0
    for leaf in layout:
        assert leaf["offset"] == off
        assert leaf["size"] == int(np.prod(leaf["shape"]))
        off += leaf["size"]
    assert off == total
    # flatten order matches layout order
    params = M.init_params(MCFG, jax.random.PRNGKey(0))
    flat = M.flatten_params(params)
    assert flat.size == total
    back = M.unflatten_params(MCFG, flat)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_shapes_and_rates():
    params = M.init_params(MCFG, jax.random.PRNGKey(1))
    logits, rates = M.forward(QCFG, MCFG, params, toks(2, 32),
                              qp_default(), jax.random.PRNGKey(2))
    assert logits.shape == (2, 32, 64)
    assert rates.shape == (4 * MCFG.n_layers + 1,)
    assert np.all(np.asarray(rates) >= 0) and np.all(np.asarray(rates) <= 1)


def test_initial_loss_near_uniform():
    params = M.init_params(MCFG, jax.random.PRNGKey(3))
    t = toks(2, 33)
    loss, (rates, per_tok) = M.loss_fn(QCFG, MCFG, params, t[:, :-1],
                                       t[:, 1:], qp_default(),
                                       jax.random.PRNGKey(4))
    assert abs(float(loss) - np.log(64)) < 0.3
    assert per_tok.shape == (2, 32)


def test_bf16_mode_deterministic_and_theta_independent():
    cfg = Q.QuantConfig(mode=Q.BF16, block=16, group=16)
    params = M.init_params(MCFG, jax.random.PRNGKey(5))
    t = toks(2, 32)
    qp1 = qp_default()
    qp2 = Q.default_qparams(MCFG.n_layers, theta0=1e-3)
    l1, _ = M.forward(cfg, MCFG, params, t, qp1, jax.random.PRNGKey(6))
    l2, _ = M.forward(cfg, MCFG, params, t, qp2, jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_theta_controls_rates_monotonically():
    params = M.init_params(MCFG, jax.random.PRNGKey(7))
    t = toks(2, 32)
    means = []
    for theta0 in [0.0, 0.5, 5.0, 1e9]:
        qp = Q.default_qparams(MCFG.n_layers, theta0=theta0)
        _, rates = M.forward(QCFG, MCFG, params, t, qp,
                             jax.random.PRNGKey(8))
        means.append(float(jnp.mean(rates)))
    assert means[0] == 1.0
    assert means[-1] == 0.0
    assert all(means[i] >= means[i + 1] for i in range(len(means) - 1))


def test_glu_and_nonglu_variants():
    ng = M.ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=2,
                       d_ff=256, seq_len=32, glu=False)
    params = M.init_params(ng, jax.random.PRNGKey(9))
    logits, _ = M.forward(QCFG, ng, params, toks(2, 32), qp_default(),
                          jax.random.PRNGKey(10))
    assert logits.shape == (2, 32, 64)
    # GLU param count differs (2f vs f input proj)
    assert ng.n_params() != MCFG.n_params()


def test_train_step_reduces_loss_on_fixed_batch():
    ts = jax.jit(T.make_train_step(QCFG, MCFG))
    flat = M.flatten_params(M.init_params(MCFG, jax.random.PRNGKey(11)))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    t = toks(2, 33, seed=12)
    theta = jnp.full((9,), 1.0)
    qs = T.default_qscalars()
    opt = jnp.array([1e-3, 0.0, 1.0])
    losses = []
    state = (flat, m, v)
    for i in range(20):
        p, m_, v_, loss, rates, gn = ts(state[0], state[1], state[2],
                                        jnp.float32(i), t, jnp.int32(i),
                                        theta, qs, opt)
        state = (p, m_, v_)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_train_step_finite_under_all_modes():
    for mode in [Q.BF16, Q.BLOCK, Q.FALLBACK, Q.JETFIRE]:
        cfg = Q.QuantConfig(mode=mode,
                            block=32 if mode == Q.JETFIRE else 16,
                            group=16,
                            nonlinear_int8=(mode == Q.JETFIRE))
        ts = jax.jit(T.make_train_step(cfg, MCFG))
        flat = M.flatten_params(
            M.init_params(MCFG, jax.random.PRNGKey(13)))
        z = jnp.zeros_like(flat)
        out = ts(flat, z, z, jnp.float32(0), toks(2, 33, seed=14),
                 jnp.int32(0), jnp.full((9,), 1.0),
                 T.default_qscalars(), jnp.array([1e-3, 0.0, 1.0]))
        assert np.isfinite(float(out[3])), mode
        assert np.all(np.isfinite(np.asarray(out[0]))), mode


def test_eval_prefix_masking_blocks_future_leakage():
    """With prefix_len = t, losses at positions < t-1 must not depend on
    tokens >= t (the Table 4 no-leakage evaluation property)."""
    ev = T.make_eval_step(QCFG, MCFG, with_prefix=True)
    params = M.flatten_params(M.init_params(MCFG, jax.random.PRNGKey(15)))
    t1 = toks(1, 33, seed=16)
    # perturb the tail beyond the prefix
    t2 = t1.at[:, 20:].set((t1[:, 20:] + 7) % 64)
    theta = jnp.full((9,), 1.0)
    qs = T.default_qscalars()
    _, per1, _ = ev(params, t1, theta, qs, jnp.int32(20))
    _, per2, _ = ev(params, t2, theta, qs, jnp.int32(20))
    np.testing.assert_allclose(np.asarray(per1)[:, :19],
                               np.asarray(per2)[:, :19], rtol=1e-5)


def test_lossless_qscalars_match_bf16():
    """levels=2^22, SR off: quantized graph ≈ bf16 graph (same tokens)."""
    params = M.init_params(MCFG, jax.random.PRNGKey(17))
    t = toks(2, 33, seed=18)
    qp = Q.default_qparams(MCFG.n_layers, theta0=np.inf)
    for k in ["levels_x", "levels_w", "levels_dy"]:
        qp[k] = jnp.float32(4194303.0)
    qp["sr_dy"] = jnp.float32(0.0)
    qp["sr_ctx"] = jnp.float32(0.0)
    qp["ctx_bits"] = jnp.float32(15.0)
    lq, _ = M.loss_fn(QCFG, MCFG, params, t[:, :-1], t[:, 1:], qp,
                      jax.random.PRNGKey(19))
    bf = Q.QuantConfig(mode=Q.BF16, block=16, group=16)
    lb, _ = M.loss_fn(bf, MCFG, params, t[:, :-1], t[:, 1:], qp,
                      jax.random.PRNGKey(19))
    assert abs(float(lq) - float(lb)) < 1e-3, (float(lq), float(lb))
