"""AOT driver tests: profile invariants, HLO-text emission, manifest."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import trainstep as T


def test_profiles_block_aligned():
    """Every profile must satisfy the block/group divisibility rules the
    quantization layout assumes."""
    for name, prof in aot.PROFILES.items():
        mc = prof.mcfg
        tokens = prof.batch * mc.seq_len
        for dim in [mc.d_model, 3 * mc.d_model, mc.d_ff, 2 * mc.d_ff,
                    tokens]:
            assert dim % prof.group == 0 or dim % prof.block == 0, \
                f"{name}: {dim} not aligned"
        assert mc.d_model % prof.group == 0, name
        assert mc.d_ff % prof.group == 0, name
        assert mc.d_model % mc.n_heads == 0, name
        assert (mc.head_dim) % 2 == 0, name  # RoPE needs even head dim


def test_hlo_text_emission_roundtrip(tmp_path):
    """to_hlo_text output must be valid HLO text with the right params."""
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text
    # must be pure text (the proto path breaks on xla_extension 0.5.1)
    assert text.isprintable() or "\n" in text


def test_emitter_writes_manifest(tmp_path):
    em = aot.Emitter(str(tmp_path))

    def fn(x):
        return (x * 2.0,)

    em.emit("double", fn, [jax.ShapeDtypeStruct((3,), jnp.float32)],
            ["x"], ["y"])
    em.save_manifest()
    man = json.loads((tmp_path / "manifest.json").read_text())
    art = man["artifacts"]["double"]
    assert art["file"] == "double.hlo.txt"
    assert art["inputs"] == [
        {"name": "x", "shape": [3], "dtype": "float32"}]
    assert art["outputs"] == [
        {"name": "y", "shape": [3], "dtype": "float32"}]
    assert os.path.exists(tmp_path / "double.hlo.txt")


def test_qscalar_names_match_unpack():
    """QSCALAR_NAMES order must match unpack_qparams indexing."""
    assert T.QSCALAR_NAMES == [
        "levels_x", "levels_w", "levels_dy", "sr_dy", "sr_ctx",
        "fallback_bwd", "crit0", "crit1", "crit2", "ctx_bits",
        "nl_in_bits"]
    qs = T.default_qscalars()
    assert qs.shape == (11,)
    from compile import model as M
    mcfg = M.ModelConfig(vocab=64, d_model=64, n_layers=3, n_heads=2,
                         d_ff=128, seq_len=32)
    theta = jnp.arange(13.0)
    qp = T.unpack_qparams(mcfg, theta, qs)
    assert qp["theta"].shape == (3, 4)
    assert float(qp["theta_head"]) == 12.0
    assert float(qp["levels_x"]) == 127.0
    assert float(qp["ctx_bits"]) == 10.0
    assert qp["crit"].shape == (3,)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_consistent():
    path = os.path.join(os.path.dirname(__file__),
                        "../../artifacts/manifest.json")
    man = json.loads(open(path).read())
    for name, art in man["artifacts"].items():
        f = os.path.join(os.path.dirname(path), art["file"])
        assert os.path.exists(f), name
        assert len(art["inputs"]) > 0
        assert len(art["outputs"]) > 0
    # every profile referenced by artifacts exists
    for name in man["artifacts"]:
        if name.startswith(("train_", "eval_", "init_", "grads_")):
            prof = name.split("_")[1]
            base = prof if prof in man["profiles"] else None
            assert base or any(
                name.split("_", 1)[1].startswith(p)
                for p in man["profiles"]), name
