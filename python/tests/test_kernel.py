"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Every kernel must match its `ref.py` oracle *exactly* on the integer path
(same rounding, same scales); allclose is only used where f32 accumulation
order may differ.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, block_quant, fallback_gemm, group_quant

jax.config.update("jax_enable_x64", False)


def rand(shape, seed=0, scale=3.0, outliers=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32) * scale
    if outliers:
        idx = rng.integers(0, x.size, size=outliers)
        x.flat[idx] *= 100.0
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# block quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [8, 16, 32])
@pytest.mark.parametrize("shape", [(32, 32), (64, 32), (32, 64), (64, 96)])
def test_block_quant_matches_ref(block, shape):
    x = rand(shape, seed=hash((block, shape)) % 2**31, outliers=4)
    q, s, am = block_quant.block_quant(x, block=block)
    qr, sr, amr = ref.block_quant_ref(x, block=block)
    qr_dense = ref.from_blocks(qr, shape)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr_dense))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(am), np.asarray(amr), rtol=1e-6)


def test_block_quant_int8_range():
    x = rand((64, 64), seed=7, scale=50.0, outliers=16)
    q, _, _ = block_quant.block_quant(x, block=16)
    qn = np.asarray(q)
    assert qn.max() <= 127 and qn.min() >= -127
    assert np.all(qn == np.round(qn))


def test_block_quant_zero_block_exact():
    x = jnp.zeros((32, 32), jnp.float32)
    q, s, am = block_quant.block_quant(x, block=16)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s) == 1.0)
    assert np.all(np.asarray(am) == 0.0)


def test_block_quant_dequant_error_bound():
    """|x - deq(q)| <= scale/2 for round-to-nearest."""
    x = rand((64, 64), seed=3, outliers=8)
    q, s, _ = block_quant.block_quant(x, block=16)
    qb = ref.to_blocks(q, 16)
    deq = ref.block_dequant_ref(qb, s, x.shape)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.repeat(np.repeat(np.asarray(s), 16, 0), 16, 1) / 2 + 1e-6
    assert np.all(err <= bound)


def test_stochastic_quant_matches_ref():
    x = rand((64, 64), seed=11)
    noise = jnp.asarray(
        np.random.default_rng(5).uniform(size=(64, 64)).astype(np.float32))
    q, s, am = block_quant.block_quant_stochastic(x, noise, block=16)
    qr, sr, _ = ref.block_quant_stochastic_ref(x, noise, block=16)
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(ref.from_blocks(qr, x.shape)))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_stochastic_rounding_unbiased():
    """E[Q_s(x)] ≈ x: average dequantized value over many noise draws."""
    x = rand((16, 16), seed=13, scale=1.0)
    rng = np.random.default_rng(17)
    acc = np.zeros((16, 16), np.float64)
    trials = 200
    for _ in range(trials):
        noise = jnp.asarray(rng.uniform(size=(16, 16)).astype(np.float32))
        q, s, _ = block_quant.block_quant_stochastic(x, noise, block=16)
        qb = ref.to_blocks(q, 16)
        acc += np.asarray(ref.block_dequant_ref(qb, s, x.shape))
    mean = acc / trials
    scale = float(np.abs(np.asarray(x)).max()) / 127.0
    # std of one draw <= scale; mean err ~ scale/sqrt(trials) * few sigma
    assert np.abs(mean - np.asarray(x)).max() < 5 * scale / np.sqrt(trials) + 1e-5


# ---------------------------------------------------------------------------
# fallback quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("theta", [0.0, 5.0, 1e9])
def test_fallback_quant_matches_ref(theta):
    x = rand((64, 64), seed=23, outliers=6)
    fq = block_quant.fallback_quant(x, jnp.float32(theta), block=16)
    fr = ref.fallback_quant_ref(x, theta, block=16)
    np.testing.assert_array_equal(
        np.asarray(fq["q"]), np.asarray(ref.from_blocks(fr["q"], x.shape)))
    np.testing.assert_array_equal(
        np.asarray(fq["rq"]), np.asarray(ref.from_blocks(fr["rq"], x.shape)))
    np.testing.assert_array_equal(np.asarray(fq["u"]), np.asarray(fr["u"]))
    np.testing.assert_allclose(np.asarray(fq["scale"]),
                               np.asarray(fr["scale"]), rtol=1e-6)
    # FMA contraction in the fused kernel perturbs the residual by ~1 ulp
    # of the first-step scale; rq still matches exactly (asserted above).
    np.testing.assert_allclose(np.asarray(fq["rscale"]),
                               np.asarray(fr["rscale"]), rtol=1e-4)


def test_fallback_theta_extremes():
    x = rand((64, 64), seed=29, outliers=6)
    all_fb = block_quant.fallback_quant(x, jnp.float32(-1.0), block=16)
    no_fb = block_quant.fallback_quant(x, jnp.float32(1e30), block=16)
    assert np.all(np.asarray(all_fb["u"]) == 1.0)
    assert np.all(np.asarray(no_fb["u"]) == 0.0)


def test_fallback_more_accurate_than_plain():
    """Fallback dequantization error far below single-step INT8."""
    x = rand((64, 64), seed=31, outliers=10)
    fr = ref.fallback_quant_ref(x, 0.0, block=16)  # all blocks fall back
    deq_fb = ref.fallback_dequant_ref(fr, x.shape)
    q, s, _ = ref.block_quant_ref(x, block=16)
    deq_plain = ref.block_dequant_ref(q, s, x.shape)
    e_fb = float(jnp.sqrt(jnp.mean((deq_fb - x) ** 2)))
    e_plain = float(jnp.sqrt(jnp.mean((deq_plain - x) ** 2)))
    assert e_fb < e_plain * 0.05  # two INT8 steps: ~127x finer resolution


def test_fallback_beats_int16_with_outliers():
    """Paper Fig 3(b): with in-block outliers, fallback < INT16 RMSE."""
    rng = np.random.default_rng(37)
    x = rng.normal(size=(128, 128)).astype(np.float32)
    idx = rng.integers(0, x.size, size=8)
    x.flat[idx] = 20000.0  # extreme sparse outliers (Fishman et al.)
    x = jnp.asarray(x)
    fr = ref.fallback_quant_ref(x, 0.0, block=128)
    deq_fb = ref.fallback_dequant_ref(fr, x.shape)
    q16, s16, _ = ref.int16_block_quant_ref(x, block=128)
    deq_16 = ref.block_dequant_ref(q16, s16, x.shape)
    e_fb = float(jnp.sqrt(jnp.mean((deq_fb - x) ** 2)))
    e_16 = float(jnp.sqrt(jnp.mean((deq_16 - x) ** 2)))
    assert e_fb < e_16


# ---------------------------------------------------------------------------
# GEMM kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mnk", [(16, 16, 16), (32, 16, 48), (16, 32, 16)])
def test_block_gemm_matches_ref(mnk):
    m, n, k = mnk
    a = rand((m, k), seed=41, outliers=2)
    b = rand((k, n), seed=43)
    qa, sa, _ = ref.block_quant_ref(a, block=16)
    qb, sb, _ = ref.block_quant_ref(b, block=16)
    qa_d = ref.from_blocks(qa, (m, k))
    qb_d = ref.from_blocks(qb, (k, n))
    c_kernel = fallback_gemm.block_gemm(qa_d, sa, qb_d, sb, block=16)
    c_ref = ref.block_gemm_ref(qa, sa, qb, sb)[:m, :n]
    np.testing.assert_allclose(np.asarray(c_kernel), np.asarray(c_ref),
                               rtol=1e-6, atol=1e-4)


def test_block_gemm_close_to_exact():
    """Quantized GEMM approximates the f32 GEMM within quant error."""
    m, n, k = 32, 32, 64
    a = rand((m, k), seed=47, scale=1.0)
    b = rand((k, n), seed=53, scale=1.0)
    c = ref.quantized_matmul_ref(a, b, block=16)
    exact = a @ b
    rel = float(jnp.linalg.norm(c - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02


@pytest.mark.parametrize("theta", [0.0, 2.0, 1e9])
def test_fallback_gemm_matches_ref(theta):
    m, n, k = 32, 32, 48
    a = rand((m, k), seed=59, outliers=6)
    b = rand((k, n), seed=61)
    fa = ref.fallback_quant_ref(a, theta, block=16)
    qb, sb, _ = ref.block_quant_ref(b, block=16)
    c_ref = ref.fallback_gemm_ref(fa["q"], fa["scale"], fa["rq"],
                                  fa["rscale"], fa["u"], qb, sb)[:m, :n]
    c_kernel = fallback_gemm.fallback_gemm(
        ref.from_blocks(fa["q"], (m, k)), fa["scale"],
        ref.from_blocks(fa["rq"], (m, k)), fa["rscale"], fa["u"],
        ref.from_blocks(qb, (k, n)), sb, block=16)
    np.testing.assert_allclose(np.asarray(c_kernel), np.asarray(c_ref),
                               rtol=1e-6, atol=1e-4)


def test_fallback_gemm_full_fallback_is_nearly_exact():
    """theta=0 (all blocks residual-corrected) ≈ exact f32 matmul."""
    m, n, k = 32, 32, 32
    a = rand((m, k), seed=67, scale=1.0, outliers=4)
    b = rand((k, n), seed=71, scale=1.0)
    c_fb, rate = ref.fallback_matmul_ref(a, b, theta=0.0, block=16)
    assert float(rate) == 1.0
    exact = a @ b
    rel = float(jnp.linalg.norm(c_fb - exact) / jnp.linalg.norm(exact))
    c_plain = ref.quantized_matmul_ref(a, b, block=16)
    rel_plain = float(jnp.linalg.norm(c_plain - exact) /
                      jnp.linalg.norm(exact))
    # B stays plain INT8, so its quantization error floors the gain;
    # fallback on A alone still cuts the total error by >2x.
    assert rel < rel_plain * 0.5


def test_fallback_error_monotone_in_theta():
    """More fallback -> lower error, monotone in theta."""
    m, n, k = 32, 32, 64
    a = rand((m, k), seed=73, outliers=12)
    b = rand((k, n), seed=79)
    exact = a @ b
    errs = []
    for theta in [0.0, 10.0, 100.0, 1e9]:
        c, _ = ref.fallback_matmul_ref(a, b, theta=theta, block=16)
        errs.append(float(jnp.linalg.norm(c - exact)))
    # theta=0 and theta=10 both residual-correct every outlier block and
    # sit at the B-quantization error floor (equal up to f32 noise).
    assert errs[0] <= errs[1] * 1.01
    assert errs[1] <= errs[2] <= errs[3]
    assert errs[0] < 0.5 * errs[3]


# ---------------------------------------------------------------------------
# group quantization (non-linear context)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4.0, 8.0, 10.0, 12.0])
def test_group_quant_matches_ref(bits):
    x = rand((16, 256), seed=83, outliers=4)
    q, s = group_quant.group_quant(x, jnp.float32(bits), group=128)
    qr, sr = ref.group_quant_ref(x, group=128, bits=bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_group_quant_roundtrip_error_decreases_with_bits():
    x = rand((16, 256), seed=89)
    errs = []
    for bits in [4.0, 6.0, 8.0, 10.0, 12.0]:
        q, s = group_quant.group_quant(x, jnp.float32(bits), group=128)
        deq = group_quant.group_dequant(q, s, group=128)
        errs.append(float(jnp.sqrt(jnp.mean((deq - x) ** 2))))
    assert all(errs[i] > errs[i + 1] for i in range(len(errs) - 1))


def test_group_dequant_matches_ref():
    x = rand((8, 128), seed=97)
    q, s = ref.group_quant_ref(x, group=128, bits=10.0)
    deq_k = group_quant.group_dequant(q, s, group=128)
    deq_r = ref.group_dequant_ref(q, s, group=128)
    np.testing.assert_array_equal(np.asarray(deq_k), np.asarray(deq_r))


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes and parameter ranges
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    mb=st.integers(1, 3), nb=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_hyp_block_quant(mb, nb, seed, scale):
    shape = (mb * 16, nb * 16)
    x = rand(shape, seed=seed, scale=scale, outliers=2)
    q, s, am = block_quant.block_quant(x, block=16)
    qr, sr, amr = ref.block_quant_ref(x, block=16)
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(ref.from_blocks(qr, shape)))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    mb=st.integers(1, 2), nb=st.integers(1, 2), kb=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    theta=st.floats(0.0, 50.0),
)
def test_hyp_fallback_gemm(mb, nb, kb, seed, theta):
    m, n, k = mb * 16, nb * 16, kb * 16
    a = rand((m, k), seed=seed, outliers=3)
    b = rand((k, n), seed=seed + 1)
    fa = ref.fallback_quant_ref(a, theta, block=16)
    qb, sb, _ = ref.block_quant_ref(b, block=16)
    c_ref = ref.fallback_gemm_ref(fa["q"], fa["scale"], fa["rq"],
                                  fa["rscale"], fa["u"], qb, sb)[:m, :n]
    c_kernel = fallback_gemm.fallback_gemm(
        ref.from_blocks(fa["q"], (m, k)), fa["scale"],
        ref.from_blocks(fa["rq"], (m, k)), fa["rscale"], fa["u"],
        ref.from_blocks(qb, (k, n)), sb, block=16)
    np.testing.assert_allclose(np.asarray(c_kernel), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 4), groups=st.integers(1, 3),
    bits=st.floats(2.0, 14.0), seed=st.integers(0, 2**31 - 1),
)
def test_hyp_group_quant(rows, groups, bits, seed):
    shape = (rows * 8, groups * 128)
    x = rand(shape, seed=seed, outliers=2)
    q, s = group_quant.group_quant(x, jnp.float32(bits), group=128)
    qr, sr = ref.group_quant_ref(x, group=128, bits=bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


# ---------------------------------------------------------------------------
# criterion metrics
# ---------------------------------------------------------------------------

def test_criterion_metrics_shapes_and_sanity():
    x = rand((64, 64), seed=101, outliers=8)
    m = ref.criterion_metrics_ref(x, block=16)
    assert m["absmax"].shape == (4, 4)
    assert np.all(np.asarray(m["l1"]) >= 0)
    assert np.all(np.asarray(m["l1rel"]) >= 0)
    assert np.all(np.asarray(m["l1rel"]) <= 1.0)
    # max block absmax == global absmax
    np.testing.assert_allclose(float(jnp.max(m["absmax"])),
                               float(jnp.max(jnp.abs(x))))
